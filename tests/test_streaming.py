"""Streaming completion mode (DESIGN.md §7): per-request hand-back with
out-of-order window finalization must leave responses, billing,
per-backend attribution AND controller state bitwise-identical to the
FIFO drain — under adversarial remote completion orders and seeded
transport faults — plus device-overlap double buffering, engine
``close()`` on a half-drained streaming run, the bounded (unrouted)
replay path, and the bench regression gate."""

from __future__ import annotations

import json
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import (AdaptiveController, ControllerConfig,
                           RemoteBackend, RemoteResponseCache, RemoteRouter,
                           RemoteTimeout, RemoteTransport, TransportConfig)
from repro.serving.engine import (BILLING_FIELDS, UNROUTED,
                                  CascadeEngine)
from repro.serving.scheduler import MicrobatchScheduler, Request


def local_apply(x):
    return x + 0.3 * jnp.sin(17.0 * x)


def remote_apply(x):
    return 5.0 * np.asarray(x)


def make_stream(rng, n, c=4, hard_frac=0.5):
    labels = rng.integers(0, c, n)
    x = rng.normal(0, 0.05, (n, c))
    margin = np.where(rng.random(n) < hard_frac, 0.1, 3.0)
    x[np.arange(n), labels] += margin
    return np.float32(x), labels


def quiet_tconf(**kw):
    base = dict(retry_backoff_s=0.0, max_retries=0, breaker_failures=10**6,
                timeout_s=60.0)
    base.update(kw)
    return TransportConfig(**base)


def build(remote=remote_apply, *, batch=8, budget=0.5, depth=4,
          mode="streaming", controller=None, cache=None, tconf=None,
          router=None):
    if router is None:
        router = RemoteTransport(remote, tconf or quiet_tconf())
    engine = CascadeEngine(local_apply, batch_size=batch,
                           remote_fraction_budget=budget, t_remote=0.0,
                           transport=router, controller=controller,
                           cache=cache)
    sched = MicrobatchScheduler(engine, fallback=lambda r: -7,
                                pipeline_depth=depth, completion_mode=mode)
    return sched, engine


def serve_all(sched, xs):
    for i, row in enumerate(xs):
        sched.submit(Request(uid=i, local_input=row, remote_input=row))
    return sched.flush()


def by_uid(responses):
    return {r.uid: (r.prediction, r.source) for r in responses}


def assert_same_accounting(e_a, e_b):
    for f in BILLING_FIELDS:
        assert getattr(e_a.stats, f) == getattr(e_b.stats, f), f
    assert e_a.stats.per_backend == e_b.stats.per_backend


# ------------------------------------------------ scheduler mode plumbing

def test_unknown_completion_mode_rejected():
    _, engine = build()
    with pytest.raises(ValueError):
        MicrobatchScheduler(engine, completion_mode="oracular")
    engine.close()


def test_streaming_responses_carry_latency_and_reorder_free_map():
    rng = np.random.default_rng(0)
    xs, _ = make_stream(rng, 24)
    sched, engine = build()
    responses = serve_all(sched, xs)
    assert sorted(r.uid for r in responses) == list(range(24))  # no drops
    assert set(sched.responses) == set(range(24))   # reorder-free map
    assert all(r.latency_s > 0.0 for r in responses)
    assert sched.first_response_s is not None
    engine.close()


# ------------------------------------- streaming == fifo equivalence

def test_streaming_matches_fifo_fixed_thresholds():
    """Static thresholds: windows finalize out of order, yet responses
    (per uid), billing and per-backend attribution must be identical to
    the FIFO drain even when later windows complete first."""
    rng = np.random.default_rng(1)
    xs, _ = make_stream(rng, 64)

    def make_reordering():
        calls = {"n": 0}
        lock = threading.Lock()

        def reordering_remote(x):
            with lock:
                calls["n"] += 1
                i = calls["n"]
            time.sleep(0.03 * max(0, 4 - i))    # first windows are slowest
            return remote_apply(x)
        return reordering_remote

    s_fifo, e_fifo = build(make_reordering(), mode="fifo")
    s_str, e_str = build(make_reordering(), mode="streaming")
    r_fifo = serve_all(s_fifo, xs)
    r_str = serve_all(s_str, xs)
    assert by_uid(r_fifo) == by_uid(r_str)
    assert_same_accounting(e_fifo, e_str)
    e_fifo.close()
    e_str.close()


def test_streaming_deterministic_across_completion_orders():
    """Same stream, adversarially inverted remote completion orders plus
    seeded per-content faults: the per-uid responses, billing and
    per-backend attribution must not depend on completion order."""
    rng = np.random.default_rng(2)
    xs, _ = make_stream(rng, 96)

    def delays_a(i):
        return 0.002 * (i % 5)

    def delays_b(i):
        return 0.002 * (4 - i % 5)          # inverted completion order

    def run(delays):
        calls = {"n": 0}
        lock = threading.Lock()

        def remote(x):
            with lock:
                calls["n"] += 1
                i = calls["n"]
            time.sleep(delays(i))
            x = np.asarray(x)
            if float(x.sum()) % 1.0 < 0.2:  # seeded per-content faults
                raise RemoteTimeout("content-keyed fault")
            return remote_apply(x)

        sched, engine = build(remote, tconf=quiet_tconf(max_in_flight=2))
        resp = serve_all(sched, xs)
        engine.close()
        return resp, engine

    r_a, e_a = run(delays_a)
    r_b, e_b = run(delays_b)
    assert by_uid(r_a) == by_uid(r_b)
    assert_same_accounting(e_a, e_b)
    assert e_a.stats.transport_failures > 0     # faults actually fired


def test_streaming_with_controller_matches_fifo_exactly():
    """A live controller couples acceptance thresholds to commit order;
    the streaming drain must reproduce the FIFO begin/commit interleaving
    so responses AND controller state stay bitwise-identical."""
    rng = np.random.default_rng(3)
    xs, _ = make_stream(rng, 96)

    def make(mode):
        ctl = AdaptiveController(ControllerConfig(
            target_remote_fraction=0.3, window=32))
        return build(mode=mode, controller=ctl)

    s_fifo, e_fifo = make("fifo")
    s_str, e_str = make("streaming")
    r_fifo = serve_all(s_fifo, xs)
    r_str = serve_all(s_str, xs)
    assert by_uid(r_fifo) == by_uid(r_str)
    assert_same_accounting(e_fifo, e_str)
    assert e_fifo.controller.state == e_str.controller.state
    e_fifo.close()
    e_str.close()


# --------------------------------------------- the point of streaming

def test_trusted_local_rows_return_before_slow_escalations():
    """Locally-trusted requests must hand back while escalations are
    still on the wire — they no longer inherit the remote p95."""
    rng = np.random.default_rng(4)
    xs, _ = make_stream(rng, 32, hard_frac=0.3)
    remote_lat = 0.15

    def slow_remote(x):
        time.sleep(remote_lat)
        return remote_apply(x)

    sched, engine = build(slow_remote, batch=8, depth=4)
    # warm the jit cache out of band, then reset accounting: measured
    # latencies must reflect serving, not first-call compilation
    engine.serve({"local": xs[:8], "remote": xs[:8]})
    engine.stats = type(engine.stats)()
    responses = serve_all(sched, xs)
    local_lat = [r.latency_s for r in responses if r.source == "local"]
    esc_lat = [r.latency_s for r in responses if r.source != "local"]
    assert local_lat and esc_lat
    # every escalated row rode at least one remote round trip; the bulk
    # of trusted-local rows returned well before that
    assert min(esc_lat) >= remote_lat
    assert np.percentile(local_lat, 95) < 0.5 * np.percentile(esc_lat, 50)
    assert sched.first_response_s < remote_lat
    engine.close()


def test_streaming_escalations_hand_back_out_of_window_order():
    """With static thresholds a fast later window's escalations must not
    wait for a slow earlier window (head-of-line) to finish."""
    rng = np.random.default_rng(5)
    xs, _ = make_stream(rng, 32, hard_frac=1.0)     # everything escalates
    order = []
    lock = threading.Lock()
    calls = {"n": 0}

    def remote(x):
        with lock:
            calls["n"] += 1
            i = calls["n"]
        time.sleep(0.2 if i == 1 else 0.0)   # first window very slow
        return remote_apply(x)

    sched, engine = build(remote, batch=8, depth=4,
                          tconf=quiet_tconf(max_in_flight=8))
    engine.serve({"local": xs[:8], "remote": xs[:8]})   # warm the jit
    engine.stats = type(engine.stats)()
    calls["n"] = 0                      # re-arm the slow first window
    for i, row in enumerate(xs):
        sched.submit(Request(uid=i, local_input=row, remote_input=row))
    for r in sched.flush():
        order.append(r.uid)
    # some row of a LATER window (uid >= 8) must hand back before the
    # last row of the first window
    first_window_done = max(order.index(u) for u in range(8))
    assert min(order.index(u) for u in range(8, 32)) < first_window_done
    engine.close()


def test_cache_hit_escalations_hand_back_before_window_drain():
    """Satellite fix (DESIGN.md §8): a cache-hit escalation needs no
    remote round trip, so in streaming mode it must hand back at the
    window's host half — its latency no longer includes the window
    drain wait behind the co-windowed misses."""
    rng = np.random.default_rng(20)
    xs, _ = make_stream(rng, 4, hard_frac=1.0)      # will fill the cache
    fresh, _ = make_stream(rng, 4, hard_frac=1.0)   # misses, same window
    delay = {"s": 0.0}

    def remote(x):
        time.sleep(delay["s"])
        return remote_apply(x)

    cache = RemoteResponseCache(64)
    sched, engine = build(remote, batch=8, budget=1.0, cache=cache)
    serve_all(sched, xs)                    # warm jit + fill the cache
    delay["s"] = 0.25                       # the misses now ride 250 ms
    mixed = np.concatenate([xs, fresh])
    for i, row in enumerate(mixed):
        sched.submit(Request(uid=100 + i, local_input=row,
                             remote_input=row))
    resp = sched.flush()
    hits = [r for r in resp if r.uid < 104]
    misses = [r for r in resp if r.uid >= 104]
    assert {r.disposition for r in hits} == {"CACHED"}
    assert all(r.cost == 0.0 for r in hits)
    assert {r.disposition for r in misses} == {"REMOTE"}
    # the fix: hits cleared the gate and returned while the misses were
    # still on the wire
    assert max(r.latency_s for r in hits) < 0.5 * delay["s"]
    assert min(r.latency_s for r in misses) >= delay["s"]
    engine.close()


def test_latency_measured_from_enqueue_consistently():
    """``Response.latency_s`` is enqueue -> hand-back in every mode:
    time a request spends queued before the flush counts."""
    rng = np.random.default_rng(21)
    xs, _ = make_stream(rng, 8)
    for mode in ("fifo", "streaming"):
        sched, engine = build(mode=mode)
        for i, row in enumerate(xs):
            sched.submit(Request(uid=i, local_input=row, remote_input=row))
        time.sleep(0.05)                # queue wait before the flush
        resp = sched.flush()
        assert all(r.latency_s >= 0.05 for r in resp), mode
        # queue_s isolates the pre-dispatch share; service latency
        # (latency_s - queue_s) excludes it
        assert all(0.05 <= r.queue_s <= r.latency_s for r in resp), mode
        engine.close()


# ------------------------------------------------ engine-level streaming

def test_engine_complete_ready_and_stream_drain():
    rng = np.random.default_rng(6)
    xs, _ = make_stream(rng, 8, hard_frac=1.0)
    _, engine = build(batch=8)
    assert engine.complete_ready() == []            # nothing in flight
    assert engine.complete_ready(block=True) == []
    fl = engine.begin_serve({"local": xs, "remote": xs}, real_rows=8)
    assert not fl.host_done                         # double-buffer parked
    engine.flush_dispatch()
    assert fl.host_done
    events = engine.complete_ready(block=True)
    assert [seq for seq, _ in events] == [fl.seq]
    assert engine.inflight == 0
    assert engine.stats.requests == 8
    # stream() drains several windows to completion
    for i in range(3):
        engine.begin_serve({"local": xs, "remote": xs}, real_rows=8)
    engine.flush_dispatch()
    seqs = [seq for seq, _ in engine.stream()]
    assert len(seqs) == 3 and engine.inflight == 0
    engine.close()


def test_double_buffer_defers_host_half_until_next_begin():
    rng = np.random.default_rng(7)
    xs, _ = make_stream(rng, 8, hard_frac=1.0)
    _, engine = build(batch=8)
    fl1 = engine.begin_serve({"local": xs, "remote": xs}, real_rows=8)
    assert not fl1.host_done            # parked: device output un-fetched
    assert fl1.pending is None          # remote NOT yet submitted
    fl2 = engine.begin_serve({"local": xs, "remote": xs}, real_rows=8)
    assert fl1.host_done                # begin(i+1) ran host half of i
    assert fl1.pending is not None      # ... which submitted its remote
    assert not fl2.host_done
    engine.close()                      # drains both, runs fl2's host half
    assert engine.stats.requests == 16


def test_engine_close_drains_half_finalized_streaming_run():
    """close() mid-stream: some windows finalized-but-uncommitted, some
    still on the wire, the newest still parked — all must be accounted
    and every pool torn down."""
    rng = np.random.default_rng(8)
    xs, _ = make_stream(rng, 8, hard_frac=1.0)
    router = RemoteRouter([RemoteBackend("r", remote_apply, quiet_tconf())])
    _, engine = build(router=router, batch=8)
    for _ in range(3):
        engine.begin_serve({"local": xs, "remote": xs}, real_rows=8)
    # finalize whatever has landed without committing everything
    engine.complete_ready()
    engine.close()
    assert engine.inflight == 0
    assert engine.stats.requests == 24              # all windows accounted
    assert engine.stats.remote_calls + engine.stats.transport_failures > 0
    for b in router:
        assert b.transport._pool is None
    engine.close()                                  # idempotent


def test_streaming_cache_still_dedups_across_flushes():
    rng = np.random.default_rng(9)
    xs, _ = make_stream(rng, 8, hard_frac=1.0)
    cache = RemoteResponseCache(64)
    sched, engine = build(batch=8, cache=cache)
    serve_all(sched, xs)                    # all escalate, all miss
    billed = engine.stats.remote_calls
    serve_all(sched, xs)                    # identical content: hits
    assert engine.stats.remote_calls == billed
    assert engine.stats.cache_hits >= 4
    engine.close()


# ------------------------------------------------ (unrouted) replay path

def mk_flaky_backend(t, down, *, reset_s=1.0, cost=0.004):
    def fn(x):
        if down["on"]:
            raise RemoteTimeout("outage")
        return remote_apply(x)
    return RemoteBackend(
        "only", fn, quiet_tconf(breaker_failures=1, breaker_reset_s=reset_s),
        cost_per_request=cost, clock=lambda: t["now"])


def test_unrouted_window_replays_after_half_open():
    """A window submitted while every breaker is open must be SERVED (and
    billed) if the breaker half-opens before its drain, instead of
    degrading to REJECTED."""
    t = {"now": 0.0}
    down = {"on": True}
    backend = mk_flaky_backend(t, down)
    router = RemoteRouter([backend])
    rng = np.random.default_rng(10)
    xs, _ = make_stream(rng, 8, hard_frac=1.0)
    _, engine = build(router=router, batch=8)

    # window 1: fails on the backend -> breaker opens
    engine.begin_serve({"local": xs, "remote": xs}, real_rows=8)
    engine.flush_dispatch()
    assert engine.complete_ready(block=True)
    assert engine.stats.per_backend["only"].transport_failures == 4

    # window 2: submitted while the breaker is open -> parked with a
    # replay ticket instead of an immediate REJECTED
    fl = engine.begin_serve({"local": xs, "remote": xs}, real_rows=8)
    engine.flush_dispatch()
    assert fl.replay_ticket and fl.pending is None
    assert router.stats.unrouted == 1

    # outage ends and the reset elapses while the window rides the
    # pipeline: the drain's replay pick serves it on the half-open probe
    down["on"] = False
    t["now"] += 2.0
    events = engine.complete_ready(block=True)
    assert len(events) == 1
    _, res = events[0]
    assert bool(res["accepted"].all())              # served, not REJECTED
    st = engine.stats
    assert st.per_backend["only"].remote_calls == 4
    assert UNROUTED not in st.per_backend           # attributed to "only"
    np.testing.assert_allclose(st.total_cost, 4 * 0.004)
    assert router.stats.replay_enqueued == 1
    assert router.stats.replay_served == 1
    assert backend.breaker.state == "closed"        # probe closed it
    engine.close()


@pytest.mark.parametrize("depth", [1, 4])
def test_replay_redeem_failure_keeps_rejected_fallback(depth):
    """Breaker still open at drain time: the parked window degrades to
    REJECTED/fallback exactly as before.

    At depth=1 window 1's failure commits at its blocking drain before
    window 2's pick, so the route/park split is structural: window 1
    fails ON the backend, window 2 parks at (unrouted).  At depth>1
    window 2's submit races window 1's breaker-opening failure on the
    transport pool, so WHERE each window's 4 failures land ("only" vs
    (unrouted)) is timing-dependent — but the OUTCOME is not: every
    escalated row fails exactly once somewhere, nothing is served or
    billed, and the replay slot is never redeemed (reset_s=1e9)."""
    t = {"now": 0.0}
    down = {"on": True}
    router = RemoteRouter([mk_flaky_backend(t, down, reset_s=1e9)])
    rng = np.random.default_rng(11)
    xs, _ = make_stream(rng, 16, hard_frac=1.0)
    sched, engine = build(router=router, batch=8, depth=depth)
    responses = serve_all(sched, xs)
    assert sorted(r.uid for r in responses) == list(range(16))
    assert {r.source for r in responses} <= {"local", "fallback"}
    st = engine.stats
    if depth == 1:
        # structural split: window 1 on-backend, window 2 parked
        assert st.per_backend["only"].transport_failures == 4
        assert st.per_backend[UNROUTED].transport_failures == 4
        assert router.stats.replay_enqueued >= 1
    assert st.transport_failures == 8       # 4 per window, wherever landed
    assert sum(u.transport_failures for u in st.per_backend.values()) == 8
    assert st.total_cost == 0.0 and st.remote_calls == 0
    assert router.stats.replay_served == 0
    engine.close()


def test_sync_serve_never_burns_replay_slots():
    """serve() finalizes in the same call, so a ticket there could never
    be served — the sync path must not inflate replay stats."""
    t = {"now": 0.0}
    down = {"on": True}
    router = RemoteRouter([mk_flaky_backend(t, down, reset_s=1e9)])
    rng = np.random.default_rng(13)
    xs, _ = make_stream(rng, 8, hard_frac=1.0)
    _, engine = build(router=router, batch=8)
    engine.serve({"local": xs, "remote": xs})   # opens the breaker
    engine.serve({"local": xs, "remote": xs})   # unrouted, sync
    assert router.stats.unrouted == 1
    assert router.stats.replay_enqueued == 0
    assert router.stats.replay_dropped == 0
    engine.close()


def test_replay_queue_is_bounded():
    t = {"now": 0.0}
    down = {"on": True}
    router = RemoteRouter([mk_flaky_backend(t, down, reset_s=1e9)],
                          replay_max=1)
    router.backends[0].breaker.record_failure()     # open (threshold 1)
    assert router.acquire_replay_slot()             # slot 1
    assert not router.acquire_replay_slot()         # bounded
    assert router.stats.replay_enqueued == 1
    assert router.stats.replay_dropped == 1
    assert router.redeem_replay() is None           # breaker still open
    assert router.acquire_replay_slot()             # slot released


@pytest.mark.parametrize("depth", [1, 4])
def test_replay_fifo_and_streaming_account_identically(depth):
    """The replay decision happens at the window's drain in both modes.

    At depth=1 the breaker-open point is structural (window 1's failure
    commits at its drain, before window 2's pick), so both modes see
    the same route/unrouted split and the accounting matches bit for
    bit INCLUDING per-backend attribution.  At depth>1 each mode races
    the transport pool independently, so the "only"-vs-(unrouted) split
    may differ between modes — the guarantee weakens to: identical
    responses per uid (a row fails to the same REJECTED/fallback
    whether it failed on the wire or was parked) and identical totals
    for every BILLING_FIELDS entry (each escalated row fails exactly
    once somewhere, nothing served, nothing billed)."""
    rng = np.random.default_rng(12)
    xs, _ = make_stream(rng, 48, hard_frac=1.0)

    def run(mode):
        t = {"now": 0.0}
        down = {"on": True}
        router = RemoteRouter([mk_flaky_backend(t, down, reset_s=1e9)])
        sched, engine = build(router=router, batch=8, depth=depth,
                              mode=mode)
        resp = serve_all(sched, xs)
        engine.close()
        return resp, engine, router

    r_f, e_f, rt_f = run("fifo")
    r_s, e_s, rt_s = run("streaming")
    assert by_uid(r_f) == by_uid(r_s)
    if depth == 1:
        assert_same_accounting(e_f, e_s)    # incl. per-backend split
    else:
        for f in BILLING_FIELDS:
            assert getattr(e_f.stats, f) == getattr(e_s.stats, f), f
        assert e_f.stats.remote_calls == 0 and e_f.stats.total_cost == 0.0
    assert rt_f.stats.replay_served == rt_s.stats.replay_served == 0


# ------------------------------------------------ bench regression gate

def test_check_regression_gate_tolerances(tmp_path):
    from benchmarks import check_regression as cr

    base = {
        "predictions_identical": True, "billing_identical": True,
        "serial": {"throughput_rps": 100.0, "p95_wall_latency_s": 0.100},
        "pipelined": {"throughput_rps": 800.0, "p95_wall_latency_s": 0.110},
        "streaming": {
            "throughput_rps": 700.0,
            "trusted_local": {"p95_latency_s": 0.004},
            "escalated": {"p95_latency_s": 0.140},
            "checks": {"zero_dropped": True, "predictions_identical": True,
                       "billing_identical": True,
                       "trusted_local_p95_halved": True},
        },
    }
    bdir = tmp_path / "baselines"
    bdir.mkdir()
    (bdir / "BENCH_serving.json").write_text(json.dumps(base))

    def run_gate(fresh):
        fp = tmp_path / "BENCH_serving.json"
        fp.write_text(json.dumps(fresh))
        return cr.main(["--serving", str(fp), "--routing", "",
                        "--chaos", "", "--baseline-dir", str(bdir)])

    # identical fresh run passes
    assert run_gate(base) == 0
    # throughput within tolerance passes; beyond tolerance fails
    ok = json.loads(json.dumps(base))
    ok["pipelined"]["throughput_rps"] = 800.0 * 0.90
    assert run_gate(ok) == 0
    bad = json.loads(json.dumps(base))
    bad["pipelined"]["throughput_rps"] = 800.0 * 0.80
    assert run_gate(bad) == 1
    # p95 rise beyond tolerance (+ absolute floor) fails
    bad = json.loads(json.dumps(base))
    bad["serial"]["p95_wall_latency_s"] = 0.100 * 1.25 + 0.021
    assert run_gate(bad) == 1
    # ms-scale p95 noise is absorbed by the absolute floor
    ok = json.loads(json.dumps(base))
    ok["streaming"]["trusted_local"]["p95_latency_s"] = 0.015
    assert run_gate(ok) == 0
    # hard checks fail regardless of tolerances
    bad = json.loads(json.dumps(base))
    bad["streaming"]["checks"]["billing_identical"] = False
    assert run_gate(bad) == 1
    # a missing tracked metric is a failure, not a silent pass
    bad = json.loads(json.dumps(base))
    del bad["streaming"]["trusted_local"]
    assert run_gate(bad) == 1
    # a FIFO-mode fresh run must not silently skip streaming checks
    bad = json.loads(json.dumps(base))
    del bad["streaming"]
    assert run_gate(bad) == 1


def test_check_regression_continuous_section(tmp_path):
    """The continuous-batching section (ISSUE 8) gates like streaming:
    hard identity/service-latency checks, presence-mismatch failure."""
    from benchmarks import check_regression as cr

    base = {
        "predictions_identical": True, "billing_identical": True,
        "serial": {"throughput_rps": 100.0, "p95_wall_latency_s": 0.100},
        "pipelined": {"throughput_rps": 800.0, "p95_wall_latency_s": 0.110},
        "continuous": {
            "throughput_rps": 700.0,
            "trusted_local": {"service_p95_latency_s": 0.001},
            "escalated": {"p95_latency_s": 0.140},
            "checks": {"zero_dropped": True, "predictions_identical": True,
                       "billing_identical": True,
                       "trusted_local_service_halved": True},
        },
    }
    bdir = tmp_path / "baselines"
    bdir.mkdir()
    (bdir / "BENCH_serving.json").write_text(json.dumps(base))

    def run_gate(fresh):
        fp = tmp_path / "BENCH_serving.json"
        fp.write_text(json.dumps(fresh))
        return cr.main(["--serving", str(fp), "--routing", "",
                        "--chaos", "", "--baseline-dir", str(bdir)])

    assert run_gate(base) == 0
    # losing bitwise identity to the window drain is a hard failure
    bad = json.loads(json.dumps(base))
    bad["continuous"]["checks"]["predictions_identical"] = False
    assert run_gate(bad) == 1
    # a fresh run silently dropping the section is a failure
    bad = json.loads(json.dumps(base))
    del bad["continuous"]
    assert run_gate(bad) == 1
    # service p95 is floor-absorbed (ms scale) but hard checks are not
    ok = json.loads(json.dumps(base))
    ok["continuous"]["trusted_local"]["service_p95_latency_s"] = 0.010
    assert run_gate(ok) == 0


def test_check_regression_kernels_gate(tmp_path):
    """--kernels gates the microbench: functional checks are hard; a
    vanished row or an order-of-magnitude us/call blowup fails."""
    from benchmarks import check_regression as cr

    base = {
        "rows": [{"kernel": "fused_head_gate", "shape": "[32,1k]x[1k,8k]",
                  "us_per_call": 1000.0, "arith_intensity": 16.0},
                 {"kernel": "confidence_gate", "shape": "[32,8192]",
                  "us_per_call": 500.0, "arith_intensity": 1.5}],
        "checks": {"fused_matches_composed": True,
                   "fused_pallas_interpret_parity": True,
                   "early_emit_fired": True},
    }
    bdir = tmp_path / "baselines"
    bdir.mkdir()
    (bdir / "BENCH_kernels.json").write_text(json.dumps(base))

    def run_gate(fresh):
        fp = tmp_path / "BENCH_kernels.json"
        fp.write_text(json.dumps(fresh))
        return cr.main(["--serving", "", "--routing", "", "--chaos", "",
                        "--kernels", str(fp),
                        "--baseline-dir", str(bdir)])

    assert run_gate(base) == 0
    # within the generous multiple passes
    ok = json.loads(json.dumps(base))
    ok["rows"][0]["us_per_call"] = 1000.0 * 2.5
    assert run_gate(ok) == 0
    # beyond it fails
    bad = json.loads(json.dumps(base))
    bad["rows"][0]["us_per_call"] = 1000.0 * 3.5 + 500.0
    assert run_gate(bad) == 1
    # a benched kernel/shape silently disappearing fails
    bad = json.loads(json.dumps(base))
    bad["rows"] = bad["rows"][1:]
    assert run_gate(bad) == 1
    # functional parity checks are hard failures
    bad = json.loads(json.dumps(base))
    bad["checks"]["early_emit_fired"] = False
    assert run_gate(bad) == 1


def test_check_regression_update_baselines(tmp_path):
    from benchmarks import check_regression as cr

    fresh = {"predictions_identical": True, "billing_identical": True,
             "serial": {"throughput_rps": 1.0, "p95_wall_latency_s": 1.0},
             "pipelined": {"throughput_rps": 1.0,
                           "p95_wall_latency_s": 1.0}}
    fp = tmp_path / "BENCH_serving.json"
    fp.write_text(json.dumps(fresh))
    bdir = tmp_path / "baselines"
    assert cr.main(["--serving", str(fp), "--routing", "",
                    "--chaos", "", "--baseline-dir", str(bdir),
                    "--update-baselines"]) == 0
    assert json.loads((bdir / "BENCH_serving.json").read_text()) == fresh
    assert cr.main(["--serving", str(fp), "--routing", "",
                    "--chaos", "", "--baseline-dir", str(bdir)]) == 0

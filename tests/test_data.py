"""Data substrate: batch iterator determinism, hash tokenizer and the
paper's input-domain reduction (§4.1), synthetic case-study generators."""

from __future__ import annotations

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data.pipeline import BatchIterator
from repro.data.synthetic import (CASE_STUDIES, calibrate_intercept,
                                  make_classification_task,
                                  sample_case_study)
from repro.data.tokenizer import PAD, UNK, HashTokenizer, reduce_domain


def test_batch_iterator_covers_epoch():
    data = {"x": np.arange(100), "y": np.arange(100) * 2}
    it = iter(BatchIterator(data, batch_size=10, seed=0))
    seen = []
    for _ in range(10):
        b = next(it)
        assert b["x"].shape == (10,)
        np.testing.assert_array_equal(b["y"], b["x"] * 2)  # rows stay paired
        seen.extend(b["x"].tolist())
    assert sorted(seen) == list(range(100))   # full epoch, no repeats


def test_batch_iterator_deterministic():
    data = {"x": np.arange(64)}
    a = [b["x"] for _, b in zip(range(4), BatchIterator(data, 16, seed=7))]
    b = [b["x"] for _, b in zip(range(4), BatchIterator(data, 16, seed=7))]
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_hash_tokenizer_roundtrip_properties():
    tok = HashTokenizer(vocab_size=1000)
    a = tok.encode("the quick brown fox", max_len=8)
    b = tok.encode("the quick brown fox", max_len=8)
    np.testing.assert_array_equal(a, b)            # deterministic
    assert a.shape == (8,)
    assert (a[4:] == PAD).all()                    # padded tail
    assert ((a[:4] >= 2) & (a[:4] < 1000)).all()   # ids in range
    # same word -> same id across positions
    c = tok.encode("fox fox", max_len=4)
    assert c[0] == c[1]


@given(st.lists(st.integers(0, 9999), min_size=1, max_size=64),
       st.integers(4, 512), st.integers(2, 64))
@settings(max_examples=40, deadline=None)
def test_reduce_domain_properties(ids, local_vocab, local_len):
    toks = np.asarray(ids, np.int32)[None]
    red = reduce_domain(toks, local_vocab, local_len)
    assert red.shape[-1] == min(len(ids), local_len)
    # every output id is PAD, UNK or a surviving in-dict id
    ok = (red == PAD) | (red == UNK) | (red < local_vocab)
    assert ok.all()
    # in-dict ids survive unchanged
    clipped = toks[..., :local_len]
    survivors = (clipped < local_vocab) | (clipped == PAD)
    np.testing.assert_array_equal(red[survivors], clipped[survivors])


def test_calibrate_intercept_hits_target():
    for target in (0.3, 0.7, 0.9):
        a = calibrate_intercept(target, slope=2.0, comp=0.5)
        rng = np.random.default_rng(0)
        z, w = rng.standard_normal(200_000), rng.standard_normal(200_000)
        acc = np.mean(1 / (1 + np.exp(-(a - 2.0 * z + 0.5 * w))))
        assert abs(acc - target) < 0.01


def test_classification_task_learnable_structure():
    toks, labels, difficulty = make_classification_task(
        0, n=512, vocab=128, seq_len=32, num_classes=4)
    assert toks.shape == (512, 32) and labels.shape == (512,)
    assert set(np.unique(labels)) <= set(range(4))
    # difficulty correlates with ambiguity: the easiest quartile should be
    # more consistently labelled than the hardest under a fresh draw
    assert np.isfinite(difficulty).all()


@pytest.mark.parametrize("name", sorted(CASE_STUDIES))
def test_case_study_sampling_reproducible(name):
    a = sample_case_study(CASE_STUDIES[name], 1000)
    b = sample_case_study(CASE_STUDIES[name], 1000)
    np.testing.assert_array_equal(a.local_correct, b.local_correct)
    np.testing.assert_array_equal(a.local_conf, b.local_conf)

"""Chaos layer + overload admission control (DESIGN.md §10, ISSUE 7):
seeded fault injection replays bit-identically on a virtual clock, the
breaker state machine survives flapping schedules (never stuck OPEN,
one half-open probe), the bounded-attempt transport deadline fires on a
hung remote, backoff is capped/jittered/deterministic, and the
scheduler's admission rules shed/degrade deterministically while
preserving zero-silent-drop and billing reconciliation."""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import (ChaosEpisode, ChaosFault, ChaosSchedule,
                           ChaosTimeout, RemoteBackend, RemoteRouter,
                           RemoteTransport, TransportConfig, VirtualClock)
from repro.runtime.chaos import ChaosRemote
from repro.runtime.transport import CLOSED, HALF_OPEN, OPEN, CircuitBreaker
from repro.serving import RequestPolicy
from repro.serving.engine import BILLING_FIELDS, CascadeEngine
from repro.serving.policy import SHED
from repro.serving.scheduler import MicrobatchScheduler, Request


def local_apply(x):
    return x + 0.3 * jnp.sin(17.0 * x)


def remote_apply(x):
    return 5.0 * np.asarray(x)


def make_stream(rng, n, c=4, hard_frac=0.5):
    labels = rng.integers(0, c, n)
    x = rng.normal(0, 0.05, (n, c))
    margin = np.where(rng.random(n) < hard_frac, 0.1, 3.0)
    x[np.arange(n), labels] += margin
    return np.float32(x), labels


def quiet_tconf(**kw):
    base = dict(retry_backoff_s=0.0, max_retries=0,
                breaker_failures=10**6, timeout_s=60.0)
    base.update(kw)
    return TransportConfig(**base)


# ------------------------------------------------------------ chaos core

def test_episode_validation_and_defaults():
    ep = ChaosEpisode("outage", 2.0, 3.0)
    assert ep.name == "outage@2" and ep.end_s == 5.0
    assert ep.covers("any", 2.0) and not ep.covers("any", 5.0)
    assert ep.progress(3.5) == 0.5
    scoped = ChaosEpisode("outage", 0.0, 1.0, backends=("a",))
    assert scoped.covers("a", 0.5) and not scoped.covers("b", 0.5)
    with pytest.raises(ValueError):
        ChaosEpisode("meteor", 0.0, 1.0)
    with pytest.raises(ValueError):
        ChaosEpisode("outage", 0.0, 0.0)
    with pytest.raises(ValueError):
        ChaosEpisode("brownout", 0.0, 1.0, rate=1.5)
    with pytest.raises(ValueError):
        ChaosSchedule([ChaosEpisode("outage", 0.0, 1.0, name="x"),
                       ChaosEpisode("flap", 2.0, 1.0, name="x")])


def test_virtual_clock_sleep_advances_and_never_rewinds():
    clk = VirtualClock(5.0)
    clk.sleep(0.25)
    assert clk() == 5.25
    clk.advance_to(4.0)             # never backwards
    assert clk() == 5.25
    clk.sleep(-1.0)                 # negative sleep is a no-op
    assert clk() == 5.25


def test_wrap_is_idempotent_and_faults_are_tagged():
    clk = VirtualClock()
    t = RemoteTransport(remote_apply, quiet_tconf(), clock=clk,
                        sleep=clk.sleep)
    sched = ChaosSchedule([ChaosEpisode("outage", 0.0, 1.0,
                                        name="ep-tag")])
    sched.wrap_transport(t, "b")
    assert isinstance(t.remote_apply, ChaosRemote)
    with pytest.raises(ValueError):
        sched.wrap_transport(t, "b")
    with pytest.raises(ChaosFault, match=r"chaos\[ep-tag\]"):
        t.remote_apply(np.zeros((1, 2), np.float32))
    assert sched.stats.by_episode == {"ep-tag": 1}


def test_brownout_draws_are_seeded_per_backend_and_replayable():
    """Same (seed, episode, backend) -> same Bernoulli stream by call
    COUNT; a different backend name gets an independent stream."""
    def draws(backend, seed, n=64):
        clk = VirtualClock(0.5)
        t = RemoteTransport(remote_apply, quiet_tconf(), clock=clk,
                            sleep=clk.sleep)
        sched = ChaosSchedule([ChaosEpisode("brownout", 0.0, 10.0,
                                            rate=0.4, name="b")],
                              seed=seed)
        sched.wrap_transport(t, backend)
        out = []
        x = np.zeros((1, 2), np.float32)
        for _ in range(n):
            try:
                t.remote_apply(x)
                out.append(False)
            except ChaosFault:
                out.append(True)
        return out

    a = draws("alpha", seed=3)
    assert a == draws("alpha", seed=3)          # bit-identical replay
    assert a != draws("beta", seed=3)           # decorrelated per backend
    assert a != draws("alpha", seed=4)          # and per schedule seed
    assert any(a) and not all(a)                # a partial brownout


def test_latency_ramp_and_timeout_storm_drive_virtual_clock():
    clk = VirtualClock()
    t = RemoteTransport(remote_apply, quiet_tconf(), clock=clk,
                        sleep=clk.sleep)
    sched = ChaosSchedule([
        ChaosEpisode("latency_ramp", 0.0, 10.0, extra_latency_s=1.0,
                     name="ramp"),
        ChaosEpisode("timeout_storm", 20.0, 5.0, extra_latency_s=0.5,
                     name="storm")])
    sched.wrap_transport(t, "b")
    x = np.zeros((1, 2), np.float32)
    clk.advance_to(5.0)                         # mid-ramp: 50% of 1.0s
    t.remote_apply(x)
    assert clk() == pytest.approx(5.5)
    clk.advance_to(21.0)
    with pytest.raises(ChaosTimeout, match=r"chaos\[storm\]"):
        t.remote_apply(x)
    assert clk() == pytest.approx(21.5)         # storm latency applied
    assert sched.stats.delayed == 2
    assert sched.stats.extra_latency_s == pytest.approx(1.0)


# ------------------------------------- breaker property-style coverage

def test_breaker_never_stuck_open_under_seeded_flapping():
    """Drive a breaker-guarded transport through a flapping schedule:
    whatever the flap does, once chaos ends and the reset elapses the
    next window must recover the breaker to CLOSED — it is never stuck
    OPEN past reset + one probe."""
    clk = VirtualClock()
    t = RemoteTransport(remote_apply,
                        quiet_tconf(breaker_failures=1,
                                    breaker_reset_s=0.5),
                        clock=clk, sleep=clk.sleep)
    sched = ChaosSchedule([ChaosEpisode("flap", 0.0, 8.0, period_s=1.0,
                                        name="f")], seed=1)
    sched.wrap_transport(t, "b")
    x = np.zeros((2, 2), np.float32)
    states = set()
    for step in range(40):                      # 0.25s steps across chaos
        clk.advance_to(0.25 * step)
        t.call(x)
        states.add(t.breaker.state)
    assert OPEN in states                       # the flap really bit
    # after the schedule ends + reset, one window closes the breaker
    clk.advance_to(sched.episodes[0].end_s + 0.6)
    logits, ok = t.call(x)
    assert ok.all() and t.breaker.state == CLOSED


def test_single_half_open_probe_and_probe_grant():
    b = CircuitBreaker(1, reset_s=1.0, clock=lambda: now["t"])
    now = {"t": 0.0}
    b.record_failure()
    assert b.state == OPEN
    assert not b.try_probe()                    # reset not elapsed
    now["t"] = 1.5
    assert b.try_probe()                        # exactly one grant...
    assert b.state == HALF_OPEN
    assert not b.try_probe()                    # ...then refused
    assert not b.would_allow()                  # no second window routed
    b.record_success()
    assert b.state == CLOSED


def test_router_pick_emits_half_open_before_failback(monkeypatch):
    """S3: the probe-granted transition happens at pick time, so the
    event log's ``open < half_open`` and ``failover < failback`` causal
    assertions hold (the old ``available()`` peek skipped HALF_OPEN)."""
    from repro.runtime.observability import EventLog
    clk = VirtualClock()
    mk = lambda name, cost: RemoteBackend(
        name, remote_apply,
        quiet_tconf(breaker_failures=1, breaker_reset_s=0.5),
        cost_per_request=cost, clock=clk, sleep=clk.sleep)
    primary, secondary = mk("primary", 0.001), mk("secondary", 0.01)
    router = RemoteRouter([primary, secondary],
                          policy="cheapest-available")
    ev = EventLog(256, clock=clk)
    router.events = ev
    for b in router.backends:
        b.transport.events = ev
        b.transport.event_source = b.name
    primary.transport.breaker.record_failure()      # open out of band
    ev.emit("breaker_open", backend="primary")      # (stand-in marker)
    assert router.pick(window=1).name == "secondary"   # failover
    clk.advance_to(1.0)                             # reset elapses
    picked = router.pick(window=2)
    assert picked.name == "primary"                 # probe granted here
    assert primary.transport.breaker.state == HALF_OPEN
    half = ev.first_seq("breaker_half_open", "primary")
    failover = ev.first_seq("router_failover")
    failback = ev.first_seq("router_failback")
    assert half is not None and failback is not None
    assert failover < half < failback               # causal order holds


# ------------------------------------------------- transport satellites

def test_bounded_attempt_abandons_hung_remote():
    """S1: a remote_apply that exceeds ``timeout_s`` is abandoned at the
    deadline (bounded wall-clock wait), counted as a timeout and a
    breaker failure — not awaited forever."""
    def hung(x):
        time.sleep(0.30)                # well past the 50ms deadline
        return remote_apply(x)

    t = RemoteTransport(hung, quiet_tconf(timeout_s=0.05,
                                          breaker_failures=1))
    t0 = time.perf_counter()
    logits, ok = t.call(np.zeros((2, 2), np.float32))
    elapsed = time.perf_counter() - t0
    assert elapsed < 0.25               # returned at the deadline
    assert logits is None and not ok.any()
    assert t.stats.timeouts == 1 and t.breaker.state == OPEN
    t.shutdown(wait=False)              # must not block on the straggler


def test_backoff_capped_exponential_with_deterministic_jitter():
    def sleeps(seed):
        out = []
        t = RemoteTransport(
            lambda x: (_ for _ in ()).throw(RuntimeError("down")),
            TransportConfig(max_retries=4, retry_backoff_s=0.01,
                            retry_backoff_cap_s=0.04,
                            retry_jitter_seed=seed,
                            breaker_failures=10**6, timeout_s=60.0),
            sleep=lambda dt: out.append(dt))
        t.call(np.zeros((1, 2), np.float32))
        return out

    a = sleeps(seed=0)
    assert len(a) == 4                  # one sleep per retry
    raws = [0.01, 0.02, 0.04, 0.04]     # doubling, clipped at the cap
    for got, raw in zip(a, raws):
        assert 0.5 * raw <= got < raw   # jitter scales into [0.5, 1.0)
    assert a == sleeps(seed=0)          # seeded -> reproducible
    assert a != sleeps(seed=1)


# ------------------------------------------- admission control (shed)

def mk_stack(*, batch=8, limit=0, soft=0.5, depth=2, mode="fifo",
             default_policy=None):
    t = RemoteTransport(remote_apply, quiet_tconf())
    engine = CascadeEngine(local_apply, batch_size=batch,
                           remote_fraction_budget=0.5, t_remote=0.0,
                           transport=t, default_policy=default_policy)
    sched = MicrobatchScheduler(engine, fallback=lambda r: -7,
                                pipeline_depth=depth,
                                completion_mode=mode,
                                admission_limit=limit,
                                admission_soft_ratio=soft)
    return sched, engine


def submit_all(sched, xs, policies=None):
    for i, row in enumerate(xs):
        pol = policies[i] if policies is not None else None
        sched.submit(Request(uid=i, local_input=row, remote_input=row,
                             policy=pol))


def test_admission_needs_runtime_path():
    engine = CascadeEngine(local_apply, remote_apply, batch_size=8,
                           remote_fraction_budget=0.5, t_remote=0.0)
    with pytest.raises(ValueError, match="admission"):
        MicrobatchScheduler(engine, admission_limit=4)
    engine.close()


def test_queue_full_always_sheds_and_soft_watermark_splits():
    """Hard bound -> SHED regardless of policy (memory safety); soft
    watermark -> the request's own ``on_miss`` arm decides."""
    rng = np.random.default_rng(5)
    xs, _ = make_stream(rng, 40)
    pols = [RequestPolicy(on_miss="reject") if i % 3 == 0 else None
            for i in range(40)]
    sched, engine = mk_stack(batch=8, limit=16, soft=0.5)
    submit_all(sched, xs, pols)
    ad = sched.admission
    assert ad.submitted == 40 and ad.admitted == 16
    # above the hard limit EVERYTHING sheds, even on_miss="fallback"
    assert ad.shed_reasons["queue_full"] == 20
    # between soft (8) and hard (16): reject arm sheds, fallback degrades
    assert ad.shed_reasons["overload"] > 0 and ad.degraded > 0
    responses = sched.flush()
    assert sorted(r.uid for r in responses) == list(range(40))
    shed = [r for r in responses if r.disposition == SHED]
    assert len(shed) == ad.shed
    assert all(r.cost == 0.0 and r.source == "shed" for r in shed)
    # reconciliation: nothing billed for shed rows, nothing dropped
    st = engine.stats
    assert ad.submitted == st.requests + ad.shed
    assert st.escalations == (st.remote_calls + st.cache_hits
                              + st.transport_failures)
    engine.close()


def test_shed_decisions_deterministic_across_runs():
    rng = np.random.default_rng(6)
    xs, _ = make_stream(rng, 64)
    pols = [RequestPolicy(on_miss="reject") if i % 4 == 0 else None
            for i in range(64)]

    def run():
        sched, engine = mk_stack(batch=8, limit=24, soft=0.5)
        submit_all(sched, xs, pols)
        resp = sched.flush()
        engine.close()
        return ([(r.uid, r.disposition) for r in
                 sorted(resp, key=lambda r: r.uid)],
                dict(sched.admission.shed_reasons))

    a, b = run(), run()
    assert a == b                       # same queue-depth trajectory
    assert any(d == SHED for _, d in a[0])


def test_deadline_feasibility_uses_service_ema():
    """With a measured window-service EMA, a deadline that cannot be met
    sheds (reject) or degrades (fallback); local-only rows that cannot
    make it are admitted anyway (degrading is a no-op for them)."""
    sched, engine = mk_stack(batch=8, limit=64, soft=1.0)
    engine.stats.window_service_ema_s = 0.5     # queue wait >= 0.5s
    row = np.zeros((4,), np.float32)

    r = sched.submit(Request(uid=0, local_input=row, remote_input=row,
                             policy=RequestPolicy(deadline_s=0.1,
                                                  on_miss="reject")))
    assert r is not None and r.disposition == SHED
    assert sched.admission.shed_reasons == {"deadline": 1}

    sched.submit(Request(uid=1, local_input=row, remote_input=row,
                         policy=RequestPolicy(deadline_s=0.1)))
    assert sched.admission.degrade_reasons == {"deadline": 1}

    sched.submit(Request(uid=2, local_input=row, remote_input=row,
                         policy=RequestPolicy(deadline_s=0.1,
                                              escalation="never")))
    assert sched.admission.degraded == 1        # no-op degrade skipped
    responses = sched.flush()
    assert sorted(r.uid for r in responses) == [0, 1, 2]
    engine.close()


def test_streaming_and_fifo_bill_identically_under_chaos():
    """The billing-identity invariant (DESIGN.md §7) survives fault
    injection: chaos decisions are count-indexed per backend and windows
    are submitted in request order in both modes, so seeded brownouts
    produce the same per-backend failures either way."""
    rng = np.random.default_rng(8)
    xs, _ = make_stream(rng, 64, hard_frac=1.0)

    def run(mode):
        clk = VirtualClock()
        t = RemoteTransport(remote_apply, quiet_tconf(), clock=clk,
                            sleep=clk.sleep)
        sched = ChaosSchedule(
            [ChaosEpisode("brownout", 0.0, 1e9, rate=0.5, name="b")],
            seed=9)
        sched.wrap_transport(t, "remote")
        engine = CascadeEngine(local_apply, batch_size=8,
                               remote_fraction_budget=0.5, t_remote=0.0,
                               transport=t, clock=clk)
        s = MicrobatchScheduler(engine, fallback=lambda r: -7,
                                pipeline_depth=2, completion_mode=mode)
        submit_all(s, xs)
        resp = s.flush()
        engine.close()
        return resp, engine, sched

    r_f, e_f, c_f = run("fifo")
    r_s, e_s, c_s = run("streaming")
    assert {r.uid: (r.prediction, r.source) for r in r_f} \
        == {r.uid: (r.prediction, r.source) for r in r_s}
    for f in BILLING_FIELDS:
        assert getattr(e_f.stats, f) == getattr(e_s.stats, f), f
    assert e_f.stats.per_backend == e_s.stats.per_backend
    assert c_f.stats.by_episode == c_s.stats.by_episode
    assert e_f.stats.transport_failures > 0     # chaos actually fired


# ------------------------------------------------------- bench smoke

def test_loadgen_traces_are_deterministic():
    from benchmarks.loadgen import generate_trace, make_features, segments

    a = generate_trace(11, pattern="pareto_burst", rate=50.0,
                       duration_s=4.0)
    b = generate_trace(11, pattern="pareto_burst", rate=50.0,
                       duration_s=4.0)
    assert [(r.uid, r.t_arrival_s, r.hard, r.policy_name)
            for r in a.requests] \
        == [(r.uid, r.t_arrival_s, r.hard, r.policy_name)
            for r in b.requests]
    xa, la = make_features(a)
    xb, lb = make_features(b)
    assert np.array_equal(xa, xb) and np.array_equal(la, lb)
    segs = list(segments(a, 1.0))
    assert len(segs) == 4
    assert sum(len(bucket) for _, bucket in segs) == len(a)
    diurnal = generate_trace(11, pattern="diurnal", rate=10.0,
                             peak_rate=80.0, duration_s=4.0)
    assert len(diurnal) > 0
    with pytest.raises(ValueError):
        generate_trace(0, pattern="tidal", rate=1.0)


def test_chaos_bench_smoke():
    """The CI scenario must pass every acceptance check (the virtual
    clock keeps the full 60s scenario to ~2s of wall time; shorter
    durations rescale the episodes and void the causal script)."""
    from benchmarks import chaos_bench

    report = chaos_bench.run(verbose=False, duration_s=60.0, seed=7,
                             json_path=None, events_jsonl=None)
    assert report["passed"], report["checks"]
    assert report["admission"]["shed"] > 0
    assert report["chaos"]["injected"] > 0

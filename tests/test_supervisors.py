"""Unit tests for the supervisor zoo (paper §3.2 / §4.2)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import supervisors as S

KEY = jax.random.PRNGKey(0)


def _logits(b=32, c=10, scale=3.0, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (b, c)) * scale


# ---------------------------------------------------------------- softmax

@pytest.mark.parametrize("name", sorted(S.SOFTMAX_SUPERVISORS))
def test_softmax_supervisor_ranges(name):
    fn = S.SOFTMAX_SUPERVISORS[name]
    conf = fn(_logits())
    assert conf.shape == (32,)
    assert bool(jnp.all(jnp.isfinite(conf)))


@pytest.mark.parametrize("name", sorted(S.SOFTMAX_SUPERVISORS))
def test_confident_beats_uniform(name):
    """Every softmax supervisor ranks a peaked distribution above a flat
    one — the property BiSupervised relies on."""
    fn = S.SOFTMAX_SUPERVISORS[name]
    peaked = jnp.array([[10.0, 0.0, 0.0, 0.0]])
    flat = jnp.zeros((1, 4))
    assert float(fn(peaked)[0]) > float(fn(flat)[0])


def test_max_softmax_values():
    conf = S.max_softmax(jnp.log(jnp.array([[0.7, 0.2, 0.1]])))
    np.testing.assert_allclose(float(conf[0]), 0.7, rtol=1e-5)


def test_pcs_values():
    conf = S.prediction_confidence_score(
        jnp.log(jnp.array([[0.7, 0.2, 0.1]])))
    np.testing.assert_allclose(float(conf[0]), 0.5, rtol=1e-5)


def test_gini_flat_is_one_over_c():
    conf = S.gini_confidence(jnp.zeros((1, 8)))
    np.testing.assert_allclose(float(conf[0]), 1 / 8, rtol=1e-5)


def test_entropy_invariant_to_logit_shift():
    lg = _logits()
    a = S.negative_entropy(lg)
    b = S.negative_entropy(lg + 100.0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


# --------------------------------------------------------------- sampling

def test_variation_ratio_unanimous_vs_split():
    unanimous = jnp.tile(jnp.array([[[9.0, 0, 0, 0]]]), (6, 1, 1))
    assert float(S.variation_ratio(unanimous)[0]) == 1.0
    split = jnp.stack([jnp.array([[9.0, 0, 0, 0]])] * 3
                      + [jnp.array([[0, 9.0, 0, 0]])] * 3)
    assert float(S.variation_ratio(split)[0]) == 0.5


def test_mutual_information_zero_when_samples_agree():
    samples = jnp.tile(_logits(4, 5, seed=2)[None], (8, 1, 1))
    mi = -S.mutual_information(samples)   # MI itself
    np.testing.assert_allclose(np.asarray(mi), 0.0, atol=1e-5)


def test_mean_max_softmax_bounds():
    conf = S.mean_max_softmax(jax.random.normal(KEY, (5, 16, 7)))
    assert bool(jnp.all((conf >= 1 / 7) & (conf <= 1.0)))


# ------------------------------------------------------------------- MDSA

def test_mdsa_flags_outliers():
    x = jax.random.normal(KEY, (512, 16))
    st = S.fit_mdsa(x)
    nominal = S.mdsa_confidence(st, x[:100])
    outlier = S.mdsa_confidence(st, x[:100] + 8.0)
    assert float(jnp.mean(nominal)) > float(jnp.mean(outlier))


def test_mdsa_is_scale_aware():
    """Mahalanobis (not Euclidean): deviation along a high-variance axis is
    less surprising than the same deviation along a low-variance axis."""
    k1, _ = jax.random.split(KEY)
    x = jax.random.normal(k1, (4096, 2)) * jnp.array([10.0, 0.1])
    st = S.fit_mdsa(x)
    hi_var = S.mdsa_confidence(st, jnp.array([[5.0, 0.0]]))
    lo_var = S.mdsa_confidence(st, jnp.array([[0.0, 5.0]]))
    assert float(hi_var[0]) > float(lo_var[0])


# ------------------------------------------------------------ autoencoder

def test_autoencoder_reconstruction_separates():
    k1, k2 = jax.random.split(KEY)
    # nominal data lives on a 2-D manifold in 16-D
    basis = jax.random.normal(k1, (2, 16))
    nominal = jax.random.normal(k2, (256, 2)) @ basis
    params = S.fit_autoencoder(KEY, nominal, latent=4, steps=300)
    on_manifold = S.autoencoder_confidence(params, nominal[:64])
    off_manifold = S.autoencoder_confidence(
        params, jax.random.normal(jax.random.PRNGKey(9), (64, 16)) * 3)
    assert float(jnp.mean(on_manifold)) > float(jnp.mean(off_manifold))


# --------------------------------------------------------------- sequence

def test_seq_min_likelihood_is_paper_reducer():
    lk = jnp.array([[0.9, 0.5, 0.8], [0.99, 0.98, 0.97]])
    out = S.seq_min_likelihood(lk)
    np.testing.assert_allclose(np.asarray(out), [0.5, 0.97], rtol=1e-6)


def test_seq_min_respects_mask():
    lk = jnp.array([[0.9, 0.1, 0.8]])
    mask = jnp.array([[1, 0, 1]])
    np.testing.assert_allclose(float(S.seq_min_likelihood(lk, mask)[0]), 0.8,
                               rtol=1e-6)


def test_seq_prod_is_length_biased_min_is_not():
    """The paper's §5.3.4 argument: product shrinks with length even for
    confident tokens; min does not."""
    short = jnp.full((1, 2), 0.9)
    long = jnp.full((1, 50), 0.9)
    assert float(S.seq_prod_likelihood(long)[0]) \
        < float(S.seq_prod_likelihood(short)[0])
    np.testing.assert_allclose(float(S.seq_min_likelihood(long)[0]),
                               float(S.seq_min_likelihood(short)[0]),
                               rtol=1e-6)


def test_equivalent_token_confidence_sums_groups():
    # vocab of 4; group 0 = {0, 1} ("negative","Negative"), group 1 = {2}
    logits = jnp.log(jnp.array([[0.4, 0.35, 0.2, 0.05]]))
    groups = jnp.array([[1, 1, 0, 0], [0, 0, 1, 0]])
    conf = S.equivalent_token_confidence(logits, groups)
    np.testing.assert_allclose(float(conf[0]), 0.75, rtol=1e-5)

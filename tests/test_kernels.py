"""Pallas kernel validation: every kernel is swept over shapes/dtypes and
asserted allclose against its ref.py pure-jnp oracle, with the kernel body
executed in interpret mode (CPU container; TPU v5e is the compile target)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.supervisors import SOFTMAX_SUPERVISORS
from repro.kernels.confidence_gate.ops import confidence_gate
from repro.kernels.confidence_gate.ref import confidence_gate_ref
from repro.kernels.decode_attention.ops import decode_attn
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.maxconf.ops import maxconf
from repro.kernels.maxconf.ref import maxconf_ref
from repro.kernels.mdsa.ops import mdsa_distance
from repro.kernels.mdsa.ref import mdsa_ref
from repro.kernels.rwkv6_scan.ops import rwkv6_time_mix_scan
from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref

KEY = jax.random.PRNGKey(0)


def rnd(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ----------------------------------------------------------------- maxconf

@pytest.mark.parametrize("b,v", [(4, 512), (8, 2048), (3, 1000), (16, 4096),
                                 (1, 5000)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_maxconf_matches_ref(b, v, dtype):
    logits = rnd(KEY, (b, v), dtype, scale=4.0)
    got = maxconf(logits, force_pallas=True, interpret=True)
    want = maxconf_ref(logits)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_array_equal(np.asarray(got["prediction"]),
                                  np.asarray(want["prediction"]))
    for k in ("max_softmax", "pcs", "entropy"):
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=tol, atol=tol, err_msg=k)


def test_maxconf_extreme_logits_stable():
    logits = jnp.array([[1e4, -1e4, 0.0] + [0.0] * 125])
    got = maxconf(logits, force_pallas=True, interpret=True)
    assert bool(jnp.all(jnp.isfinite(got["max_softmax"])))
    np.testing.assert_allclose(float(got["max_softmax"][0]), 1.0, atol=1e-5)


# ---------------------------------------------------------- confidence gate

@pytest.mark.parametrize("supervisor", sorted(SOFTMAX_SUPERVISORS))
@pytest.mark.parametrize("b,v", [(8, 128), (4, 512), (3, 100), (16, 1000)])
def test_confidence_gate_matches_ref(supervisor, b, v):
    logits = rnd(jax.random.fold_in(KEY, b * v), (b, v), scale=4.0)
    got = confidence_gate(logits, supervisor=supervisor,
                          force_pallas=True, interpret=True)
    want = confidence_gate_ref(logits, supervisor=supervisor)
    np.testing.assert_allclose(np.asarray(got["conf"]),
                               np.asarray(want["conf"]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got["pred"]),
                                  np.asarray(want["pred"]))
    np.testing.assert_array_equal(np.asarray(got["idx"]),
                                  np.asarray(want["idx"]))


@pytest.mark.parametrize("supervisor", sorted(SOFTMAX_SUPERVISORS))
def test_confidence_gate_threshold_and_validity(supervisor):
    """t_local gates eligibility; rows >= n_valid (padding) never appear;
    unused slots are -1; idx ascends by confidence."""
    b, v = 12, 256
    logits = rnd(jax.random.fold_in(KEY, 99), (b, v), scale=4.0)
    conf = np.asarray(SOFTMAX_SUPERVISORS[supervisor](logits))
    n_valid, k = 9, 6
    # threshold between two rows' confidences, never ON one (a t equal to
    # a row's exact conf would flip on last-ulp kernel/ref differences)
    srt = np.sort(conf[:n_valid])
    t = float(0.5 * (srt[3] + srt[4]))
    got = confidence_gate(logits, t, n_valid, supervisor=supervisor, k=k,
                          force_pallas=True, interpret=True)
    want = confidence_gate_ref(logits, t, n_valid, supervisor=supervisor,
                               k=k)
    np.testing.assert_array_equal(np.asarray(got["idx"]),
                                  np.asarray(want["idx"]))
    idx = np.asarray(got["idx"])
    sel = idx[idx >= 0]
    assert (sel < n_valid).all()
    assert (conf[sel] < t).all()
    assert (np.diff(conf[sel]) >= 0).all()          # ascending confidence
    # every eligible valid row not selected has conf >= the selected max
    rest = np.setdiff1d(np.arange(n_valid), sel)
    if sel.size and sel.size < k:
        assert (conf[rest] >= t).all()              # gate exhausted


def test_confidence_gate_extreme_logits_stable():
    logits = jnp.array([[1e4, -1e4, 0.0] + [0.0] * 125] * 8)
    for sup in sorted(SOFTMAX_SUPERVISORS):
        got = confidence_gate(logits, supervisor=sup, force_pallas=True,
                              interpret=True)
        assert bool(jnp.all(jnp.isfinite(got["conf"]))), sup


def test_confidence_gate_callable_supervisor_falls_back():
    """Callable supervisors (paper §4.2) take the jnp path everywhere."""
    def margin(logits):
        top2 = jax.lax.top_k(logits, 2)[0]
        return top2[..., 0] - top2[..., 1]

    logits = rnd(KEY, (8, 64), scale=2.0)
    got = confidence_gate(logits, supervisor=margin, k=4, force_pallas=True)
    want = confidence_gate_ref(logits, supervisor=margin, k=4)
    np.testing.assert_allclose(np.asarray(got["conf"]),
                               np.asarray(want["conf"]), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(got["idx"]),
                                  np.asarray(want["idx"]))


def test_confidence_gate_early_emit_fires_inside_jit():
    """The early-emit host callback (ISSUE 8) must fire exactly once per
    gate call from INSIDE a jitted computation, tagged with the dispatch
    seq and carrying the same conf/pred/idx the gate returns."""
    logits = rnd(jax.random.fold_in(KEY, 7), (8, 64), scale=4.0)
    fired = []

    def emit(tag, conf, pred, idx):
        fired.append((int(tag), np.asarray(pred).copy(),
                      np.asarray(idx).copy()))

    out = jax.jit(lambda x: confidence_gate(
        x, 0.5, supervisor="max_softmax", k=4, emit=emit,
        emit_tag=11))(logits)
    jax.block_until_ready(out["pred"])
    assert len(fired) == 1
    tag, pred, idx = fired[0]
    assert tag == 11
    np.testing.assert_array_equal(pred, np.asarray(out["pred"]))
    np.testing.assert_array_equal(idx, np.asarray(out["idx"]))


# --------------------------------------------------------- fused head->gate

def _fused_mats(seed, b, d, v):
    k1 = jax.random.fold_in(KEY, seed)
    h = rnd(k1, (b, d), scale=1.0)
    w = rnd(jax.random.fold_in(k1, 1), (d, v), scale=1.0 / np.sqrt(d))
    bias = rnd(jax.random.fold_in(k1, 2), (v,), scale=0.1)
    return h, w, bias


@pytest.mark.parametrize("supervisor", sorted(SOFTMAX_SUPERVISORS))
@pytest.mark.parametrize("b,d,v", [(8, 128, 512), (3, 64, 100),
                                   (12, 96, 640)])
def test_fused_head_gate_matches_ref(supervisor, b, d, v):
    """Pallas body (interpret mode) vs the jnp oracle. pred/idx must be
    bitwise identical; conf tolerates summation-order noise from folding
    the vocab in 128-wide blocks (neg_entropy amplifies it through the
    cancellation in its epilogue, hence the 2e-4 rtol)."""
    from repro.kernels.fused_head_gate.ops import fused_head_gate
    from repro.kernels.fused_head_gate.ref import fused_head_gate_ref
    h, w, bias = _fused_mats(b * d * v, b, d, v)
    got = fused_head_gate(h, w, bias, supervisor=supervisor,
                          force_pallas=True, interpret=True)
    want = fused_head_gate_ref(h, w, bias, supervisor=supervisor)
    np.testing.assert_allclose(np.asarray(got["conf"]),
                               np.asarray(want["conf"]),
                               rtol=2e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got["pred"]),
                                  np.asarray(want["pred"]))
    np.testing.assert_array_equal(np.asarray(got["idx"]),
                                  np.asarray(want["idx"]))


def test_fused_head_gate_matches_composed_gate():
    """Fusing the projection must not change the gate's contract: the
    ref oracle equals confidence_gate_ref over the composed logits, and
    threshold/validity/k semantics carry over unchanged."""
    from repro.kernels.fused_head_gate.ops import fused_head_gate
    from repro.kernels.fused_head_gate.ref import fused_head_gate_ref
    b, d, v = 12, 64, 256
    h, w, bias = _fused_mats(5, b, d, v)
    logits = h @ w + bias
    for sup in sorted(SOFTMAX_SUPERVISORS):
        conf = np.asarray(SOFTMAX_SUPERVISORS[sup](logits))
        srt = np.sort(conf[:9])
        t = float(0.5 * (srt[3] + srt[4]))
        fused = fused_head_gate_ref(h, w, bias, t, 9, supervisor=sup, k=6)
        composed = confidence_gate_ref(logits, t, 9, supervisor=sup, k=6)
        np.testing.assert_array_equal(np.asarray(fused["idx"]),
                                      np.asarray(composed["idx"]), sup)
        np.testing.assert_array_equal(np.asarray(fused["pred"]),
                                      np.asarray(composed["pred"]), sup)
        # pallas body honours the same threshold/validity contract
        pal = fused_head_gate(h, w, bias, t, 9, supervisor=sup, k=6,
                              force_pallas=True, interpret=True)
        np.testing.assert_array_equal(np.asarray(pal["idx"]),
                                      np.asarray(composed["idx"]), sup)


def test_fused_local_head_is_drop_in_local_apply():
    """FusedLocalHead composes trunk -> projection when called like a
    plain local_apply (the engine's non-fused paths and billing-parity
    A/B rely on this)."""
    from repro.kernels.fused_head_gate.ops import FusedLocalHead
    b, d, v = 4, 32, 64
    h, w, bias = _fused_mats(6, b, d, v)
    head = FusedLocalHead(trunk=lambda x: 2.0 * x, w=w, bias=bias)
    np.testing.assert_allclose(np.asarray(head(h)),
                               np.asarray((2.0 * h) @ w + bias),
                               rtol=1e-5, atol=1e-5)


def test_fused_head_gate_dim_mismatch_raises():
    from repro.kernels.fused_head_gate.ops import fused_head_gate
    h, w, _ = _fused_mats(8, 4, 32, 64)
    with pytest.raises(ValueError):
        fused_head_gate(h, w[:16], None)


# -------------------------------------------------------------------- mdsa

@pytest.mark.parametrize("b,d", [(8, 64), (128, 128), (100, 200), (1, 32)])
def test_mdsa_matches_ref(b, d):
    k1, k2 = jax.random.split(KEY)
    x = rnd(k1, (b, d))
    mean = rnd(k2, (d,))
    a = rnd(jax.random.fold_in(KEY, 7), (d, d), scale=0.3)
    prec = a @ a.T + jnp.eye(d)              # SPD
    got = mdsa_distance(x, mean, prec, force_pallas=True, interpret=True)
    want = mdsa_ref(x, mean, prec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------------- flash attention

@pytest.mark.parametrize("t,h,kh,hd", [(256, 4, 4, 64), (512, 8, 2, 64),
                                       (256, 4, 1, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(t, h, kh, hd, causal):
    ks = jax.random.split(KEY, 3)
    q = rnd(ks[0], (2, t, h, hd))
    k = rnd(ks[1], (2, t, kh, hd))
    v = rnd(ks[2], (2, t, kh, hd))
    got = attention(q, k, v, causal=causal, force_pallas=True,
                    interpret=True)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_sliding_window():
    ks = jax.random.split(KEY, 3)
    q = rnd(ks[0], (1, 512, 4, 64))
    k = rnd(ks[1], (1, 512, 4, 64))
    v = rnd(ks[2], (1, 512, 4, 64))
    got = attention(q, k, v, causal=True, window=128, force_pallas=True,
                    interpret=True)
    want = attention_ref(q, k, v, causal=True, window=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_flash_attention_bf16(dtype):
    ks = jax.random.split(KEY, 3)
    q = rnd(ks[0], (1, 256, 4, 64), dtype)
    k = rnd(ks[1], (1, 256, 4, 64), dtype)
    v = rnd(ks[2], (1, 256, 4, 64), dtype)
    got = attention(q, k, v, causal=True, force_pallas=True, interpret=True)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


# -------------------------------------------------------- decode attention

@pytest.mark.parametrize("b,s,h,kh,hd", [(2, 1024, 8, 2, 64),
                                         (4, 2048, 4, 4, 64),
                                         (1, 512, 16, 2, 128)])
def test_decode_attention_matches_ref(b, s, h, kh, hd):
    ks = jax.random.split(KEY, 3)
    q = rnd(ks[0], (b, h, hd))
    kc = rnd(ks[1], (b, s, kh, hd))
    vc = rnd(ks[2], (b, s, kh, hd))
    kv_len = jnp.asarray(
        np.random.default_rng(0).integers(1, s + 1, (b,)), jnp.int32)
    got = decode_attn(q, kc, vc, kv_len, force_pallas=True, interpret=True)
    want = decode_attention_ref(q, kc, vc, kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------- rwkv scan

@pytest.mark.parametrize("t,h,m", [(128, 2, 32), (256, 4, 64), (64, 1, 16)])
def test_rwkv6_scan_matches_ref(t, h, m):
    ks = jax.random.split(KEY, 5)
    b = 2
    r = rnd(ks[0], (b, t, h, m), scale=0.5)
    k = rnd(ks[1], (b, t, h, m), scale=0.5)
    v = rnd(ks[2], (b, t, h, m), scale=0.5)
    w = jax.nn.sigmoid(rnd(ks[3], (b, t, h, m)))   # decay in (0, 1)
    u = rnd(ks[4], (h, m), scale=0.5)
    s0 = jnp.zeros((b, h, m, m), jnp.float32)
    got_y, got_s = rwkv6_time_mix_scan(r, k, v, w, u, s0, force_pallas=True,
                                       interpret=True)
    want_y, want_s = rwkv6_scan_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               rtol=2e-3, atol=2e-3)


def test_rwkv6_scan_state_carry():
    """Scanning two halves with carried state == scanning the whole."""
    ks = jax.random.split(KEY, 5)
    b, t, h, m = 1, 64, 2, 16
    r = rnd(ks[0], (b, t, h, m), scale=0.5)
    k = rnd(ks[1], (b, t, h, m), scale=0.5)
    v = rnd(ks[2], (b, t, h, m), scale=0.5)
    w = jax.nn.sigmoid(rnd(ks[3], (b, t, h, m)))
    u = rnd(ks[4], (h, m), scale=0.5)
    s0 = jnp.zeros((b, h, m, m), jnp.float32)
    y_full, s_full = rwkv6_scan_ref(r, k, v, w, u, s0)
    half = t // 2
    y1, s1 = rwkv6_scan_ref(r[:, :half], k[:, :half], v[:, :half],
                            w[:, :half], u, s0)
    y2, s2 = rwkv6_scan_ref(r[:, half:], k[:, half:], v[:, half:],
                            w[:, half:], u, s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=1e-5, atol=1e-5)

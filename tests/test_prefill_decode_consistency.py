"""Prefill/decode equivalence: decoding token-by-token after a prefill must
reproduce the logits a longer prefill would compute.

This pins the KV-cache plumbing (incl. the SWA ring buffer and the
recurrent-state carry of RWKV6/Mamba2) against the full-sequence path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving.generate import greedy_generate

# one representative per cache mechanism
CACHE_ARCHS = [
    "yi-6b",              # plain GQA cache
    "qwen2-7b",           # GQA + QKV bias
    "h2o-danube-1.8b",    # sliding-window ring buffer
    "deepseek-v2-lite-16b",  # MLA latent cache + MoE
    "rwkv6-1.6b",         # recurrent state
    "zamba2-7b",          # mamba2 state + shared-attn KV
]


def _setup(arch):
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    return cfg, params


@pytest.mark.parametrize("arch", CACHE_ARCHS)
def test_decode_matches_prefill(arch):
    cfg, params = _setup(arch)
    rng = np.random.default_rng(0)
    t = 96 if not cfg.sliding_window else 96  # > window (64) for SWA archs
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, t)), jnp.int32)

    # ground truth: prefill over the full t tokens
    want, _ = T.prefill(cfg, params, {"tokens": toks})

    # prefill t-1, decode the final token through the serving cache
    logits, pcache = T.prefill(cfg, params, {"tokens": toks[:, :-1]})
    cache = T.make_cache(cfg, 2, t + 4)

    def graft(d, s):
        if d.shape == s.shape:
            return s
        return jax.lax.dynamic_update_slice_in_dim(d, s, 0, axis=2)

    cache = jax.tree.map(graft, cache, pcache)
    got, _ = T.decode_step(cfg, params, toks[:, -1], cache,
                           jnp.int32(t - 1))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)
    # the decode path must pick the same next token
    assert bool(jnp.all(jnp.argmax(got, -1) == jnp.argmax(want, -1))), arch


@pytest.mark.parametrize("arch", ["yi-6b", "rwkv6-1.6b"])
def test_greedy_generate_is_self_consistent(arch):
    """Token i chosen by the decode loop == argmax of a fresh prefill over
    prompt + tokens[:i]."""
    cfg, params = _setup(arch)
    rng = np.random.default_rng(3)
    prompt = jnp.asarray(rng.integers(1, cfg.vocab_size, (1, 32)), jnp.int32)
    toks, liks = greedy_generate(cfg, params, {"tokens": prompt},
                                 max_new_tokens=4)
    assert toks.shape == (1, 4) and liks.shape == (1, 4)
    assert bool(jnp.all((liks > 0) & (liks <= 1)))
    seq = prompt
    for i in range(4):
        logits, _ = T.prefill(cfg, params, {"tokens": seq})
        assert int(jnp.argmax(logits, -1)[0]) == int(toks[0, i]), (arch, i)
        seq = jnp.concatenate([seq, toks[:, i:i + 1]], axis=1)

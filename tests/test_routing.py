"""Multi-remote tier registry + routing (DESIGN.md §6): backend registry
and policy ordering, breaker-driven speculative failover at submit time,
per-backend billing/latency attribution (never double-billed), fail-back
after half-open recovery, dollar-budget control, engine lifecycle
(close/context manager), and determinism of routing + billing under
adversarial remote completion orders (test_pipeline.py style)."""

from __future__ import annotations

import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import (AdaptiveController, ControllerConfig,
                           RemoteBackend, RemoteResponseCache, RemoteRouter,
                           RemoteTimeout, RemoteTransport, TransportConfig)
from repro.runtime.calibration import calibrate, select_operating_point
from repro.serving.engine import (BILLING_FIELDS, UNROUTED,
                                  CascadeEngine)
from repro.serving.scheduler import MicrobatchScheduler, Request


def local_apply(x):
    return x + 0.3 * jnp.sin(17.0 * x)


def remote_apply(x):
    return 5.0 * np.asarray(x)


def make_stream(rng, n, c=4, hard_frac=0.5):
    labels = rng.integers(0, c, n)
    x = rng.normal(0, 0.05, (n, c))
    margin = np.where(rng.random(n) < hard_frac, 0.1, 3.0)
    x[np.arange(n), labels] += margin
    return np.float32(x), labels


def quiet_tconf(**kw):
    base = dict(retry_backoff_s=0.0, max_retries=0, breaker_failures=10**6,
                timeout_s=60.0)
    base.update(kw)
    return TransportConfig(**base)


def build(router, *, batch=8, budget=0.5, depth=1, controller=None,
          cache=None):
    engine = CascadeEngine(local_apply, batch_size=batch,
                           remote_fraction_budget=budget, t_remote=0.0,
                           transport=router, controller=controller,
                           cache=cache)
    sched = MicrobatchScheduler(engine, fallback=lambda r: -7,
                                pipeline_depth=depth)
    return sched, engine


def serve_all(sched, xs):
    for i, row in enumerate(xs):
        sched.submit(Request(uid=i, local_input=row, remote_input=row))
    return sched.flush()


def routing(responses):
    return [(r.uid, r.prediction, r.source) for r in responses]


def usage_sum(stats, field):
    return sum(getattr(u, field) for u in stats.per_backend.values())


def assert_backend_invariants(stats):
    """escalations = Σ_backends (remote_calls + cache_hits + failures);
    total_cost = Σ_backends cost (exactly, same-order float folds)."""
    assert stats.escalations == (usage_sum(stats, "remote_calls")
                                 + usage_sum(stats, "cache_hits")
                                 + usage_sum(stats, "transport_failures"))
    assert stats.remote_calls == usage_sum(stats, "remote_calls")
    assert stats.cache_hits == usage_sum(stats, "cache_hits")
    assert stats.transport_failures == usage_sum(stats, "transport_failures")
    np.testing.assert_allclose(stats.total_cost, usage_sum(stats, "cost"),
                               rtol=0, atol=1e-12)


# --------------------------------------------------- registry + policies

def test_backend_owns_transport_and_latency_stats():
    t = {"now": 0.0}

    def remote(x):
        t["now"] += 0.1              # each window "takes" 100ms
        return remote_apply(x)

    b = RemoteBackend("fast", remote, quiet_tconf(),
                      cost_per_request=0.008, latency_s=0.5,
                      clock=lambda: t["now"])
    assert b.latency_estimate() == 0.5           # modelled prior, no calls
    logits, ok = b.call(np.float32(np.eye(4)))
    assert ok.all()
    np.testing.assert_allclose(logits, 5.0 * np.eye(4))
    assert b.stats.latency_ema_s == pytest.approx(0.1)
    assert b.latency_estimate() == pytest.approx(0.1)   # measured wins
    assert b.stats.latency_percentile(95) == pytest.approx(0.1)
    assert b.stats.mean_latency_s == pytest.approx(0.1)
    assert b.available()


def test_backend_wraps_existing_transport():
    tr = RemoteTransport(remote_apply, quiet_tconf())
    b = RemoteBackend("legacy", transport=tr)
    assert b.transport is tr and b.cost_per_request is None
    with pytest.raises(ValueError):
        RemoteBackend("nothing")                 # no callable, no transport


def test_router_policy_candidate_order():
    a = RemoteBackend("a", remote_apply, quiet_tconf(),
                      cost_per_request=0.008, latency_s=0.1)
    b = RemoteBackend("b", remote_apply, quiet_tconf(),
                      cost_per_request=0.002, latency_s=0.3)
    c = RemoteBackend("c", remote_apply, quiet_tconf())   # unknown cost
    names = lambda r: [x.name for x in r.candidates()]
    assert names(RemoteRouter([a, b, c])) == ["a", "b", "c"]
    assert names(RemoteRouter([a, b, c],
                              policy="cheapest-available")) == ["b", "a", "c"]
    r = RemoteRouter([a, b, c], policy="latency-ema")
    assert names(r) == ["c", "a", "b"]           # unknown prior = 0.0
    # measured EMA reorders: b becomes the fastest observed backend
    b.stats.record_latency(0.01)
    c.stats.record_latency(0.5)
    assert names(r) == ["b", "a", "c"]
    assert r.expected_cost_per_escalation(0.123) == 0.002
    assert RemoteRouter([c]).expected_cost_per_escalation(0.123) == 0.123


def test_router_validates_configuration():
    a = RemoteBackend("a", remote_apply, quiet_tconf())
    with pytest.raises(ValueError):
        RemoteRouter([])
    with pytest.raises(ValueError):
        RemoteRouter([a, RemoteBackend("a", remote_apply, quiet_tconf())])
    with pytest.raises(ValueError):
        RemoteRouter([a], policy="round-robin")
    with pytest.raises(KeyError):
        RemoteRouter([a]).backend("missing")


def test_router_pick_fails_over_on_open_breaker_and_recovers():
    t = {"now": 0.0}
    mk = lambda name: RemoteBackend(
        name, remote_apply,
        quiet_tconf(breaker_failures=1, breaker_reset_s=10.0),
        clock=lambda: t["now"])
    primary, standby = mk("primary"), mk("standby")
    router = RemoteRouter([primary, standby])
    assert router.pick() is primary
    primary.breaker.record_failure()             # opens (threshold 1)
    assert not primary.available()
    assert router.pick() is standby              # speculative failover
    assert router.stats.failovers == 1
    t["now"] = 11.0                              # past breaker_reset_s
    assert primary.available()                   # half-open probe due
    assert router.pick() is primary              # automatic fail-back
    assert router.stats.picks == {"primary": 2, "standby": 1}


def test_router_unrouted_when_every_breaker_open():
    t = {"now": 0.0}
    backends = [RemoteBackend(
        n, remote_apply, quiet_tconf(breaker_failures=1, breaker_reset_s=99),
        clock=lambda: t["now"]) for n in ("a", "b")]
    router = RemoteRouter(backends)
    for b in backends:
        b.breaker.record_failure()
    assert router.pick() is None
    assert router.stats.unrouted == 1


# ------------------------------------- single-backend == raw transport

def test_single_backend_registry_bitwise_matches_raw_transport():
    rng = np.random.default_rng(0)
    xs, _ = make_stream(rng, 64)

    tr = RemoteTransport(remote_apply, quiet_tconf())
    s_raw, e_raw = build(tr)
    router = RemoteRouter([RemoteBackend("remote", remote_apply,
                                         quiet_tconf())])
    s_reg, e_reg = build(router, depth=4)

    r_raw = serve_all(s_raw, xs)
    r_reg = serve_all(s_reg, xs)
    assert routing(r_raw) == routing(r_reg)
    for f in BILLING_FIELDS:
        assert getattr(e_raw.stats, f) == getattr(e_reg.stats, f), f
    # the auto-wrapped raw transport attributes identically to the
    # explicit single-backend registry
    assert e_raw.stats.per_backend == e_reg.stats.per_backend
    assert_backend_invariants(e_reg.stats)
    e_raw.close()
    e_reg.close()


# --------------------------------------------- failover accounting

def test_failover_serves_all_requests_and_never_double_bills():
    rng = np.random.default_rng(1)
    xs, _ = make_stream(rng, 48, hard_frac=1.0)   # everything escalates

    def down(x):
        raise RemoteTimeout("primary outage")

    primary = RemoteBackend("primary", down,
                            quiet_tconf(breaker_failures=1),
                            cost_per_request=0.002)
    secondary = RemoteBackend("secondary", remote_apply, quiet_tconf(),
                              cost_per_request=0.008)
    router = RemoteRouter([primary, secondary])
    sched, eng = build(router, batch=8, budget=0.5)
    responses = serve_all(sched, xs)

    assert sorted(r.uid for r in responses) == list(range(48))   # no drops
    st = eng.stats
    # window 1 fails on the primary (4 escalations lost, $0), every later
    # window speculatively fails over to the secondary
    assert st.per_backend["primary"].remote_calls == 0
    assert st.per_backend["primary"].cost == 0.0
    assert st.per_backend["primary"].transport_failures == 4
    assert st.per_backend["secondary"].transport_failures == 0
    assert st.per_backend["secondary"].remote_calls == st.remote_calls == 20
    np.testing.assert_allclose(st.per_backend["secondary"].cost,
                               20 * 0.008)
    np.testing.assert_allclose(st.total_cost, 20 * 0.008)
    assert router.stats.failovers == 5
    assert_backend_invariants(st)
    eng.close()


def test_failback_after_half_open_recovery():
    t = {"now": 0.0}
    down = {"on": True}

    def primary_fn(x):
        t["now"] += 0.01
        if down["on"]:
            raise RemoteTimeout("outage")
        return remote_apply(x)

    primary = RemoteBackend(
        "primary", primary_fn,
        quiet_tconf(breaker_failures=1, breaker_reset_s=1.0),
        cost_per_request=0.001, clock=lambda: t["now"])
    secondary = RemoteBackend("secondary", remote_apply, quiet_tconf(),
                              cost_per_request=0.01,
                              clock=lambda: t["now"])
    router = RemoteRouter([primary, secondary])
    sched, eng = build(router, batch=8, budget=0.5)
    rng = np.random.default_rng(2)

    def one_batch():
        xs, _ = make_stream(rng, 8, hard_frac=1.0)
        return serve_all(sched, xs)

    one_batch()                       # primary fails -> breaker opens
    assert eng.stats.per_backend["primary"].transport_failures == 4
    one_batch()                       # routed to the secondary
    assert eng.stats.per_backend["secondary"].remote_calls == 4
    down["on"] = False
    t["now"] += 2.0                   # past breaker_reset_s: half-open due
    one_batch()                       # fail-back: primary serves again
    assert eng.stats.per_backend["primary"].remote_calls == 4
    assert primary.breaker.state == "closed"
    np.testing.assert_allclose(eng.stats.total_cost,
                               4 * 0.01 + 4 * 0.001)
    assert_backend_invariants(eng.stats)
    eng.close()


def test_unrouted_windows_degrade_to_fallback_and_attribute():
    rng = np.random.default_rng(3)
    xs, _ = make_stream(rng, 16, hard_frac=1.0)

    def down(x):
        raise RemoteTimeout("down")

    router = RemoteRouter([RemoteBackend(
        "only", down, quiet_tconf(breaker_failures=1, breaker_reset_s=1e9),
        cost_per_request=0.004)])
    sched, eng = build(router, batch=8, budget=0.5)
    responses = serve_all(sched, xs)
    assert sorted(r.uid for r in responses) == list(range(16))
    assert {r.source for r in responses} == {"local", "fallback"}
    # window 1 fails on the backend; window 2 is unrouted (breaker open)
    st = eng.stats
    assert st.per_backend["only"].transport_failures == 4
    assert st.per_backend[UNROUTED].transport_failures == 4
    assert st.total_cost == 0.0 and st.remote_calls == 0
    assert router.stats.unrouted == 1
    assert_backend_invariants(st)
    eng.close()


# --------------------------------------------- determinism under reorder

def test_routing_deterministic_under_adversarial_completion_orders():
    """Two-backend registry, pre-opened primary breaker + seeded
    per-content faults on the secondary: FIFO drain must make responses,
    aggregate billing AND per-backend attribution identical under
    inverted remote completion orders."""
    rng = np.random.default_rng(4)
    xs, _ = make_stream(rng, 96)

    def delays_a(i):
        return 0.002 * (i % 5)

    def delays_b(i):
        return 0.002 * (4 - i % 5)

    def run(delays):
        calls = {"n": 0}
        lock = threading.Lock()

        def flaky_secondary(x):
            with lock:
                calls["n"] += 1
                i = calls["n"]
            time.sleep(delays(i))
            x = np.asarray(x)
            if float(x.sum()) % 1.0 < 0.2:       # content-keyed faults
                raise RemoteTimeout("seeded fault")
            return remote_apply(x)

        primary = RemoteBackend(
            "primary", remote_apply,
            quiet_tconf(breaker_failures=1, breaker_reset_s=1e9),
            cost_per_request=0.001)
        primary.breaker.record_failure()          # deterministically open
        secondary = RemoteBackend("secondary", flaky_secondary,
                                  quiet_tconf(max_in_flight=2),
                                  cost_per_request=0.009)
        sched, eng = build(RemoteRouter([primary, secondary]),
                           batch=8, budget=0.5, depth=4)
        resp = serve_all(sched, xs)
        eng.close()
        return resp, eng

    r_a, e_a = run(delays_a)
    r_b, e_b = run(delays_b)
    assert routing(r_a) == routing(r_b)
    for f in BILLING_FIELDS:
        assert getattr(e_a.stats, f) == getattr(e_b.stats, f), f
    assert e_a.stats.per_backend == e_b.stats.per_backend
    assert e_a.stats.per_backend["secondary"].remote_calls > 0
    assert "primary" not in e_a.stats.per_backend   # never routed to
    assert_backend_invariants(e_a.stats)


def test_multi_backend_pipelined_matches_serial_when_healthy():
    """Healthy registry, cheapest-available policy: a deep pipeline must
    bill and answer exactly like depth=1, and all traffic goes to the
    cheapest backend."""
    rng = np.random.default_rng(5)
    xs, _ = make_stream(rng, 64)

    def mk():
        cheap = RemoteBackend("cheap", remote_apply, quiet_tconf(),
                              cost_per_request=0.002)
        fast = RemoteBackend("fast", remote_apply, quiet_tconf(),
                             cost_per_request=0.008)
        return RemoteRouter([fast, cheap], policy="cheapest-available")

    s_ser, e_ser = build(mk(), batch=8)
    s_pip, e_pip = build(mk(), batch=8, depth=4)
    assert routing(serve_all(s_ser, xs)) == routing(serve_all(s_pip, xs))
    for f in BILLING_FIELDS:
        assert getattr(e_ser.stats, f) == getattr(e_pip.stats, f), f
    assert e_ser.stats.per_backend == e_pip.stats.per_backend
    assert "fast" not in e_pip.stats.per_backend    # never routed to
    assert e_pip.stats.per_backend["cheap"].cost == e_pip.stats.total_cost
    e_ser.close()
    e_pip.close()


# --------------------------------------------- cache attribution

def test_cache_hits_attribute_to_filling_backend():
    rng = np.random.default_rng(6)
    xs, _ = make_stream(rng, 8, hard_frac=1.0)
    cache = RemoteResponseCache(64)
    router = RemoteRouter([RemoteBackend("filler", remote_apply,
                                         quiet_tconf(),
                                         cost_per_request=0.005)])
    sched, eng = build(router, batch=8, budget=0.5, cache=cache)
    serve_all(sched, xs)                       # fills entries via "filler"
    billed = eng.stats.remote_calls
    serve_all(sched, xs)                       # identical content: hits
    st = eng.stats
    assert st.remote_calls == billed           # no re-billing
    assert st.per_backend["filler"].cache_hits == st.cache_hits == 4
    np.testing.assert_allclose(st.total_cost, billed * 0.005)
    assert_backend_invariants(st)
    eng.close()


def test_cache_lookup_returns_source_and_legacy_get_still_works():
    cache = RemoteResponseCache(4)
    k = b"k"
    cache.put(k, np.float32([1.0]), source="gpt-big")
    val, src = cache.lookup(k)
    np.testing.assert_allclose(val, [1.0])
    assert src == "gpt-big"
    np.testing.assert_allclose(cache.get(k), [1.0])   # value-only API
    cache.put(b"legacy", np.float32([2.0]))           # no source recorded
    assert cache.lookup(b"legacy")[1] is None


# --------------------------------------------- engine lifecycle

def test_engine_close_drains_windows_and_shuts_pools():
    rng = np.random.default_rng(7)
    xs, _ = make_stream(rng, 8, hard_frac=1.0)
    router = RemoteRouter([RemoteBackend("r", remote_apply, quiet_tconf())])
    _, eng = build(router, batch=8)
    eng.begin_serve({"local": xs, "remote": xs}, real_rows=8)
    assert eng.inflight == 1
    eng.close()
    assert eng.inflight == 0
    assert eng.stats.requests == 8             # drained windows accounted
    for b in router:
        assert b.transport._pool is None       # pools torn down
    eng.close()                                # idempotent


def test_engine_context_manager_closes():
    rng = np.random.default_rng(8)
    xs, _ = make_stream(rng, 8)
    router = RemoteRouter([RemoteBackend("r", remote_apply, quiet_tconf())])
    with CascadeEngine(local_apply, batch_size=8,
                       remote_fraction_budget=0.5, t_remote=0.0,
                       transport=router) as eng:
        eng.begin_serve({"local": xs, "remote": xs}, real_rows=8)
    assert eng.inflight == 0
    assert router.backends[0].transport._pool is None


def test_fused_engine_close_is_noop():
    eng = CascadeEngine(local_apply, lambda x: 5.0 * jnp.asarray(x),
                        batch_size=8, remote_fraction_budget=0.5,
                        t_remote=0.0)
    eng.close()                                # no transport: nothing to do


# --------------------------------------------- dollar budget control

def test_controller_holds_dollar_budget_across_price_change():
    """Fraction mode would keep escalating 20% regardless of price; the
    dollar budget must instead halve the fraction when the blended price
    per escalation doubles (e.g. failover onto a pricier backend)."""
    rng = np.random.default_rng(9)
    budget = 0.0008                            # $/request
    ctl = AdaptiveController(ControllerConfig(
        target_remote_fraction=0.2, window=256,
        cost_budget_per_request=budget))
    b = 32

    def run_phase(price, batches):
        esc = req = spend = 0.0
        for _ in range(batches):
            conf = np.where(rng.random(b) < 0.8, rng.uniform(0.8, 1.0, b),
                            rng.uniform(0.3, 0.7, b))
            cap = ctl.capacity(b)
            t = ctl.t_local
            k = min(cap, b) if t is None else min(int((conf < t).sum()), cap)
            ctl.observe(conf, k, b, cost=k * price)
            esc += k
            req += b
            spend += k * price
        return esc / req, spend / req

    run_phase(0.004, 96)                       # settle at $0.004/escalation
    frac_cheap, spend_cheap = run_phase(0.004, 64)
    assert abs(frac_cheap - 0.2) <= 0.04       # 0.0008 / 0.004 = 0.2
    assert abs(spend_cheap - budget) <= 0.2 * budget
    run_phase(0.008, 96)                       # price doubles (failover)
    frac_dear, spend_dear = run_phase(0.008, 64)
    assert abs(frac_dear - 0.1) <= 0.04        # 0.0008 / 0.008 = 0.1
    assert abs(spend_dear - budget) <= 0.2 * budget
    assert ctl.state.ema_cost_per_escalation == pytest.approx(0.008,
                                                              rel=0.05)
    assert ctl.state.effective_target == pytest.approx(0.1, rel=0.1)


def test_calibration_cost_budget_selection():
    rng = np.random.default_rng(10)
    hard = rng.random(512) < 0.4
    lc = np.where(hard, rng.uniform(0.2, 0.6, 512),
                  rng.uniform(0.7, 1.0, 512))
    lok = rng.random(512) < np.where(hard, 0.3, 0.95)
    rc = rng.uniform(0.5, 1.0, 512)
    rok = rng.random(512) < 0.97
    price = 0.01
    point, k, front = calibrate(lc, lok, rc, rok, cost_budget=0.002,
                                batch_size=32, grid=17,
                                remote_cost_per_request=price)
    assert point.cost_per_request <= 0.002 + 1e-12
    assert point.remote_fraction <= 0.2 + 1e-9     # 0.002 / 0.01
    assert 1 <= k <= 32
    with pytest.raises(ValueError):
        select_operating_point(front)              # no budget at all
    with pytest.raises(ValueError):
        select_operating_point(front, 0.2, cost_budget=0.002)  # both

"""Serving runtime: cascade engine (capacity escalation), microbatch
scheduler routing (local/remote/fallback), cost & latency accounting."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.engine import CascadeEngine, CostModel, make_cascade_step
from repro.serving.scheduler import MicrobatchScheduler, Request


def _toy_appliers(c=4):
    """Local: logits from a weak linear map; remote: near-oracle logits.
    Inputs are one-hot-ish feature vectors whose argmax is the label."""

    def local_apply(x):          # x: [B, C] features
        return x + 0.3 * jnp.sin(17.0 * x)   # noisy view

    def remote_apply(x):
        return 5.0 * x                        # confident, accurate

    return local_apply, remote_apply


def _batch(rng, b=16, c=4, hard_frac=0.5):
    """hard inputs have small margins -> low local confidence."""
    labels = rng.integers(0, c, b)
    x = rng.normal(0, 0.05, (b, c))
    margin = np.where(rng.random(b) < hard_frac, 0.1, 3.0)
    x[np.arange(b), labels] += margin
    return {"local": jnp.asarray(x, jnp.float32),
            "remote": jnp.asarray(x, jnp.float32)}, labels, margin


def test_cascade_step_escalates_lowest_confidence():
    local_apply, remote_apply = _toy_appliers()
    step = jax.jit(make_cascade_step(local_apply, remote_apply, capacity=8))
    rng = np.random.default_rng(0)
    batch, labels, margin = _batch(rng, b=16)
    out = step(batch)
    esc = np.asarray(out["escalated"])
    assert esc.sum() == 8
    # escalated inputs are exactly the 8 lowest-confidence ones
    conf = np.asarray(out["local_conf"])
    assert conf[esc].max() <= conf[~esc].min() + 1e-6
    # hard inputs (small margin) should dominate the escalated set
    assert margin[esc].mean() < margin[~esc].mean()


def test_cascade_engine_accounting():
    local_apply, remote_apply = _toy_appliers()
    cost = CostModel(local_latency_s=0.05, remote_latency_s=0.32,
                     remote_cost_per_request=0.0048)
    eng = CascadeEngine(local_apply, remote_apply, batch_size=16,
                        remote_fraction_budget=0.25, t_remote=0.1,
                        cost=cost)
    rng = np.random.default_rng(1)
    for _ in range(4):
        batch, _, _ = _batch(rng)
        eng.serve(batch)
    st = eng.stats
    assert st.requests == 64
    assert st.remote_calls == 16          # 25% capacity exactly
    np.testing.assert_allclose(st.remote_fraction, 0.25)
    np.testing.assert_allclose(st.total_cost, 16 * 0.0048)
    # paper Eq. 2: mean latency = t_l + r * t_r
    np.testing.assert_allclose(st.mean_latency_s, 0.05 + 0.25 * 0.32,
                               rtol=1e-6)


def test_engine_runtime_threshold_reconfiguration():
    """Paper §4.5: thresholds are runtime-tunable configuration."""
    local_apply, remote_apply = _toy_appliers()
    eng = CascadeEngine(local_apply, remote_apply, batch_size=8,
                        remote_fraction_budget=0.5, t_remote=0.99)
    rng = np.random.default_rng(2)
    batch, _, _ = _batch(rng, b=8)
    strict = eng.serve(dict(batch))
    eng.set_remote_threshold(0.0)
    lax = eng.serve(dict(batch))
    assert (~np.asarray(strict["accepted"])).sum() \
        >= (~np.asarray(lax["accepted"])).sum()
    assert np.asarray(lax["accepted"]).all()


def test_scheduler_routes_and_falls_back():
    local_apply, remote_apply = _toy_appliers()
    eng = CascadeEngine(local_apply, remote_apply, batch_size=8,
                        remote_fraction_budget=0.5, t_remote=0.9999999)
    sched = MicrobatchScheduler(eng, fallback=lambda req: -7)
    rng = np.random.default_rng(3)
    batch, labels, _ = _batch(rng, b=20)   # not a multiple of 8 -> padding
    x = np.asarray(batch["local"])
    for i in range(20):
        sched.submit(Request(uid=i, local_input=x[i], remote_input=x[i]))
    responses = sched.flush()
    assert len(responses) == 20
    srcs = {r.source for r in responses}
    assert srcs <= {"local", "remote", "fallback"}
    assert "local" in srcs
    for r in responses:
        if r.source == "fallback":
            assert r.prediction == -7
    # every uid answered exactly once
    assert sorted(r.uid for r in responses) == list(range(20))


def test_scheduler_accuracy_beats_local_only():
    """System-level sanity: the cascade's accuracy approaches the remote
    tier's on hard inputs while keeping remote calls at the budget."""
    local_apply, remote_apply = _toy_appliers()
    eng = CascadeEngine(local_apply, remote_apply, batch_size=32,
                        remote_fraction_budget=0.5, t_remote=0.0)
    rng = np.random.default_rng(4)
    batch, labels, _ = _batch(rng, b=32, hard_frac=0.5)
    out = eng.serve(batch)
    cascade_acc = (np.asarray(out["prediction"]) == labels).mean()
    local_acc = (np.asarray(out["local_pred"]) == labels).mean()
    assert cascade_acc >= local_acc
    assert eng.stats.remote_fraction == 0.5


def test_engine_accepts_callable_supervisor():
    """Paper §4.2: MDSA (or any callable) as the 1st-level supervisor."""
    import jax.numpy as jnp

    local_apply, remote_apply = _toy_appliers()

    def margin_supervisor(logits):            # custom confidence fn
        top2 = jax.lax.top_k(logits, 2)[0]
        return top2[..., 0] - top2[..., 1]

    eng = CascadeEngine(local_apply, remote_apply, batch_size=16,
                        remote_fraction_budget=0.25, t_remote=0.0,
                        supervisor=margin_supervisor)
    rng = np.random.default_rng(5)
    batch, labels, margin = _batch(rng, b=16)
    out = eng.serve(batch)
    esc = np.asarray(out["escalated"])
    assert esc.sum() == 4
    # the low-margin (hard) inputs get escalated under the custom metric
    assert margin[esc].mean() < margin[~esc].mean()

"""Launch-layer policy tests: the serving fsdp auto-policy and the
TP-footprint estimator (pure; no multi-device runtime needed)."""

from __future__ import annotations


from repro.configs import get_config
from repro.launch.specs import _tp_param_bytes_per_chip
from tests.test_sharding_rules import FakeMesh


class _Mesh(FakeMesh):
    pass


MESH = _Mesh({"data": 16, "model": 16})


def test_tp_footprint_orders_models():
    small = _tp_param_bytes_per_chip(get_config("h2o-danube-1.8b"), MESH)
    mid = _tp_param_bytes_per_chip(get_config("deepseek-67b"), MESH)
    big = _tp_param_bytes_per_chip(get_config("qwen3-moe-235b-a22b"), MESH)
    assert small < mid < big


def test_tp_footprint_matches_napkin_math():
    """deepseek-67b: ~67B params bf16 / 16-way TP ~= 8.4 GB/chip."""
    got = _tp_param_bytes_per_chip(get_config("deepseek-67b"), MESH)
    assert 6e9 < got < 11e9, got


def test_serving_policy_thresholds():
    """67B fits pure-TP (A1 applies); qwen3-235B does not (keeps FSDP)."""
    assert _tp_param_bytes_per_chip(get_config("deepseek-67b"), MESH) < 12e9
    assert _tp_param_bytes_per_chip(get_config("qwen3-moe-235b-a22b"),
                                    MESH) > 12e9

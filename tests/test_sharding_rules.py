"""Sharding-plan unit tests (no multi-device runtime needed: PartitionSpec
construction is pure) + a subprocess smoke of the real dry-run entrypoint."""

from __future__ import annotations

import os
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.launch import sharding as sh
from repro.launch.specs import input_specs, params_specs


class FakeMesh:
    """Duck-typed mesh: .shape mapping + .axis_names (enough for the
    rules; building a real 256-device mesh needs XLA_FLAGS)."""
    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)
        self.size = 1
        for v in shape.values():
            self.size *= v


MESH = FakeMesh({"data": 16, "model": 16})


def _spec(cfg, path_names, fsdp=True):
    shapes = params_specs(cfg)
    node = shapes
    for k in path_names:
        node = node[k]
    # rebuild the path objects via tree_map_with_path lookup
    from jax.tree_util import tree_flatten_with_path
    flat, _ = tree_flatten_with_path(shapes)
    for path, leaf in flat:
        names = [str(getattr(p, "key", "")) for p in path]
        if names == list(path_names):
            return sh.param_spec(path, leaf, MESH, fsdp=fsdp)
    raise KeyError(path_names)


def test_column_parallel_attention_proj():
    cfg = get_config("yi-6b")
    spec = _spec(cfg, ("blocks", "attn", "wq", "w"))
    assert spec == P(None, "data", "model")    # [L, D, H*hd]


def test_row_parallel_output_proj():
    cfg = get_config("yi-6b")
    spec = _spec(cfg, ("blocks", "attn", "wo", "w"))
    assert spec == P(None, "model", "data")    # [L, H*hd, D]


def test_moe_expert_parallel():
    cfg = get_config("qwen3-moe-235b-a22b")
    spec = _spec(cfg, ("blocks", "moe", "w_gate"))
    assert spec == P(None, "model", "data", None)   # [L, E, d, f]


def test_vocab_parallel_head_and_embed():
    cfg = get_config("deepseek-67b")
    assert _spec(cfg, ("head", "w")) == P("data", "model")
    assert _spec(cfg, ("embed",)) == P("data", "model")


def test_indivisible_head_stays_replicated():
    cfg = get_config("hubert-xlarge")           # 504 classes, 504 % 16 != 0
    assert _spec(cfg, ("head", "w")) == P(None, None)


def test_norms_replicated():
    cfg = get_config("qwen2-7b")
    assert _spec(cfg, ("final_norm",)) == P(None)
    assert _spec(cfg, ("blocks", "norm1")) == P(None, None)


def test_qkv_bias_sharded_with_column():
    cfg = get_config("qwen2-7b")                # attn_bias=True
    assert _spec(cfg, ("blocks", "attn", "wq", "b")) == P(None, "model")


def test_mla_latent_projections():
    cfg = get_config("deepseek-v2-lite-16b")
    assert _spec(cfg, ("blocks", "attn", "w_uk", "w")) \
        == P(None, "data", "model")


def test_no_fsdp_without_flag():
    cfg = get_config("yi-6b")
    spec = _spec(cfg, ("blocks", "attn", "wq", "w"), fsdp=False)
    assert spec == P(None, None, "model")


@pytest.mark.parametrize("arch", list_archs())
def test_input_specs_cover_all_applicable_shapes(arch):
    from repro.configs import shape_applicable
    cfg = get_config(arch)
    for name, shape in INPUT_SHAPES.items():
        if not shape_applicable(cfg, shape)[0]:
            continue
        specs = input_specs(cfg, shape)
        leaves = jax.tree.leaves(specs)
        assert leaves, (arch, name)
        for l in leaves:
            assert isinstance(l, jax.ShapeDtypeStruct)
        if shape.kind == "decode":
            assert specs["token"].shape == (shape.global_batch,)
            assert specs["pos"].shape == ()


@pytest.mark.slow
def test_dryrun_entrypoint_end_to_end():
    """Real 256-device lower+compile through the CLI (subprocess so the
    XLA device-count flag doesn't leak into this test session)."""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "rwkv6-1.6b", "--shape", "decode_32k"],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout

"""Adaptive cascade runtime: offline calibration, online budget control,
fault-aware transport (circuit breaker), response cache — plus the
scheduler's REJECTED -> fallback path, padding-aware accounting and
TriSupervised tier-routing invariants (no hypothesis required)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cascade import (EDGE, LOCAL, REJECTED, REMOTE, TriThresholds,
                                trisupervised_batch)
from repro.runtime.cache import RemoteResponseCache, content_key
from repro.runtime.calibration import (calibrate, pareto_frontier,
                                       select_operating_point,
                                       sweep_operating_points)
from repro.runtime.controller import (AdaptiveController, ControllerConfig,
                                      population_stability_index)
from repro.runtime.transport import (CLOSED, HALF_OPEN, OPEN, CircuitBreaker,
                                     RemoteTimeout, RemoteTransport,
                                     TransportConfig)
from repro.serving.engine import CascadeEngine
from repro.serving.scheduler import MicrobatchScheduler, Request


# ------------------------------------------------------------ helpers

def local_apply(x):
    return x + 0.3 * jnp.sin(17.0 * x)


def remote_apply(x):
    return 5.0 * np.asarray(x)


def make_stream(rng, n, c=4, hard_frac=0.5):
    labels = rng.integers(0, c, n)
    x = rng.normal(0, 0.05, (n, c))
    margin = np.where(rng.random(n) < hard_frac, 0.1, 3.0)
    x[np.arange(n), labels] += margin
    return np.float32(x), labels


def runtime_engine(remote=remote_apply, *, batch=8, budget=0.5,
                   t_remote=0.0, tconf=None, **kw):
    transport = RemoteTransport(remote, tconf or TransportConfig(
        retry_backoff_s=0.0, max_retries=1, breaker_failures=2))
    return CascadeEngine(local_apply, batch_size=batch,
                         remote_fraction_budget=budget, t_remote=t_remote,
                         transport=transport, **kw), transport


# ------------------------------------------------------------ cache

def test_cache_content_keys_and_lru():
    a = np.arange(6, dtype=np.int32)
    assert content_key(a) == content_key(a.copy())
    assert content_key(a) != content_key(a.astype(np.float32))
    assert content_key({"t": a, "i": 0}) == content_key({"i": 0, "t": a})
    cache = RemoteResponseCache(capacity=2)
    k1, k2, k3 = (content_key(np.float32([i])) for i in range(3))
    cache.put(k1, np.float32([1.0]))
    cache.put(k2, np.float32([2.0]))
    assert cache.get(k1) is not None      # refreshes k1
    cache.put(k3, np.float32([3.0]))      # evicts k2 (LRU)
    assert cache.get(k2) is None
    assert cache.get(k1) is not None
    assert cache.stats.evictions == 1
    assert cache.stats.hits == 2 and cache.stats.misses == 1


def test_engine_cache_dedups_billing():
    rng = np.random.default_rng(0)
    cache = RemoteResponseCache(256)
    eng, _ = runtime_engine(batch=8, budget=0.5, cache=cache)
    x, _ = make_stream(rng, 8, hard_frac=1.0)
    eng.serve({"local": x, "remote": x})
    first_billed = eng.stats.remote_calls
    assert first_billed == 4              # capacity = 50% of 8
    eng.serve({"local": x, "remote": x})  # identical content
    assert eng.stats.remote_calls == first_billed       # no new billing
    assert eng.stats.cache_hits == 4
    assert eng.stats.escalations == 8
    np.testing.assert_allclose(
        eng.stats.total_cost,
        first_billed * eng.cost.remote_cost_per_request)


# ------------------------------------------------------------ transport

def test_circuit_breaker_state_machine():
    t = {"now": 0.0}
    br = CircuitBreaker(failures=2, reset_s=10.0, clock=lambda: t["now"])
    assert br.state == CLOSED and br.allow()
    br.record_failure()
    assert br.state == CLOSED
    br.record_failure()
    assert br.state == OPEN and not br.allow()
    t["now"] = 11.0
    assert br.allow() and br.state == HALF_OPEN
    br.record_failure()                    # probe fails -> straight open
    assert br.state == OPEN
    t["now"] = 22.0
    assert br.allow()
    br.record_success()
    assert br.state == CLOSED and br.consecutive_failures == 0


def test_transport_retries_then_succeeds():
    attempts = {"n": 0}

    def flaky(x):
        attempts["n"] += 1
        if attempts["n"] == 1:
            raise ConnectionError("transient")
        return remote_apply(x)

    tr = RemoteTransport(flaky, TransportConfig(
        max_in_flight=8, max_retries=2, retry_backoff_s=0.0))
    logits, ok = tr.call(np.float32(np.eye(4)))
    assert ok.all()
    assert tr.stats.retries == 1 and tr.stats.errors == 1
    np.testing.assert_allclose(logits, 5.0 * np.eye(4))


def test_transport_partial_window_failure():
    calls = {"n": 0}

    def half_broken(x):
        calls["n"] += 1
        if calls["n"] % 2 == 0:
            raise RemoteTimeout("down")
        return remote_apply(x)

    tr = RemoteTransport(half_broken, TransportConfig(
        max_in_flight=2, max_retries=0, retry_backoff_s=0.0,
        breaker_failures=100))
    logits, ok = tr.call(np.float32(np.eye(4)))    # 2 windows of 2
    assert ok.tolist() == [True, True, False, False]
    assert tr.stats.failed_requests == 2
    np.testing.assert_allclose(logits[:2], 5.0 * np.eye(4)[:2])


def test_breaker_short_circuits_and_recovers():
    t = {"now": 0.0}
    down = {"on": True}

    def remote(x):
        t["now"] += 0.01
        if down["on"]:
            raise RemoteTimeout("outage")
        return remote_apply(x)

    tr = RemoteTransport(remote, TransportConfig(
        max_in_flight=4, max_retries=0, retry_backoff_s=0.0,
        breaker_failures=1, breaker_reset_s=1.0),
        clock=lambda: t["now"], sleep=lambda s: None)
    _, ok = tr.call(np.float32(np.eye(4)))
    assert not ok.any() and tr.breaker.state == OPEN
    _, ok = tr.call(np.float32(np.eye(4)))        # still open: no attempts
    assert tr.stats.short_circuited >= 4
    down["on"] = False
    t["now"] += 2.0                                # past reset window
    logits, ok = tr.call(np.float32(np.eye(4)))    # half-open probe wins
    assert ok.all() and tr.breaker.state == CLOSED


# ------------------------------------------------- scheduler + fallback

def test_outage_degrades_to_fallback_without_drops():
    rng = np.random.default_rng(1)
    eng, tr = runtime_engine(lambda x: (_ for _ in ()).throw(
        RemoteTimeout("down")), batch=8, budget=0.5)
    sched = MicrobatchScheduler(eng, fallback=lambda req: -7)
    x, _ = make_stream(rng, 20)
    for i in range(20):
        sched.submit(Request(uid=i, local_input=x[i], remote_input=x[i]))
    responses = sched.flush()
    assert sorted(r.uid for r in responses) == list(range(20))   # no drops
    srcs = {r.source for r in responses}
    assert srcs == {"local", "fallback"}          # outage -> no "remote"
    for r in responses:
        if r.source == "fallback":
            assert r.prediction == -7
    assert sched.fallbacks == sum(r.source == "fallback" for r in responses)
    assert eng.stats.transport_failures == sched.fallbacks
    assert eng.stats.remote_calls == 0 and eng.stats.total_cost == 0.0


def test_scheduler_fallback_receives_original_request():
    rng = np.random.default_rng(2)
    eng, _ = runtime_engine(lambda x: (_ for _ in ()).throw(
        RemoteTimeout("down")), batch=4, budget=0.5)
    seen: list[int] = []

    def fallback(req: Request) -> int:
        seen.append(req.uid)
        return 100 + req.uid

    sched = MicrobatchScheduler(eng, fallback=fallback)
    x, _ = make_stream(rng, 8, hard_frac=1.0)
    for i in range(8):
        sched.submit(Request(uid=i, local_input=x[i], remote_input=x[i]))
    responses = sched.flush()
    fb = [r for r in responses if r.source == "fallback"]
    assert len(fb) == len(seen) > 0
    for r in fb:
        assert r.prediction == 100 + r.uid        # the request itself

def test_scheduler_without_fallback_returns_sentinel():
    rng = np.random.default_rng(3)
    eng, _ = runtime_engine(lambda x: (_ for _ in ()).throw(
        RemoteTimeout("down")), batch=4, budget=0.5)
    sched = MicrobatchScheduler(eng, fallback=None)
    x, _ = make_stream(rng, 4, hard_frac=1.0)
    for i in range(4):
        sched.submit(Request(uid=i, local_input=x[i], remote_input=x[i]))
    preds = {r.prediction for r in sched.flush() if r.source == "fallback"}
    assert preds == {-1}


# ------------------------------------------------- padding accounting

@pytest.mark.parametrize("fused", [True, False])
def test_padded_rows_not_billed(fused):
    rng = np.random.default_rng(4)
    if fused:
        eng = CascadeEngine(local_apply, lambda x: 5.0 * jnp.asarray(x),
                            batch_size=8, remote_fraction_budget=0.5,
                            t_remote=0.0)
    else:
        eng, _ = runtime_engine(batch=8, budget=0.5)
    sched = MicrobatchScheduler(eng)
    x, _ = make_stream(rng, 11, hard_frac=1.0)    # 8 + 3 (padded to 8)
    for i in range(11):
        sched.submit(Request(uid=i, local_input=x[i], remote_input=x[i]))
    responses = sched.flush()
    assert len(responses) == 11
    assert eng.stats.requests == 11               # padded replicas unbilled
    assert eng.stats.remote_calls <= 8            # k=4 + k<=4 real in tail
    np.testing.assert_allclose(
        eng.stats.total_cost,
        eng.stats.remote_calls * eng.cost.remote_cost_per_request)
    np.testing.assert_allclose(
        eng.stats.total_latency_s,
        11 * eng.cost.local_latency_s
        + eng.stats.remote_calls * eng.cost.remote_latency_s)


def test_fused_padded_tail_escalations_capped_to_real_rows():
    eng = CascadeEngine(local_apply, lambda x: 5.0 * jnp.asarray(x),
                        batch_size=8, remote_fraction_budget=1.0,
                        t_remote=0.0)
    rng = np.random.default_rng(5)
    x, _ = make_stream(rng, 3, hard_frac=1.0)
    batch = {"local": np.concatenate([x, np.repeat(x[-1:], 5, 0)]),
             "remote": np.concatenate([x, np.repeat(x[-1:], 5, 0)])}
    eng.serve(batch, real_rows=3)
    assert eng.stats.requests == 3
    assert eng.stats.remote_calls == 3            # not 8


# ------------------------------------------------- controller

def _conf_stream(rng, n, easy_frac):
    """Synthetic 1st-level confidences: mixture of easy (high) / hard."""
    easy = rng.random(n) < easy_frac
    return np.where(easy, rng.uniform(0.8, 1.0, n),
                    rng.uniform(0.3, 0.7, n))


def test_controller_tracks_budget_under_drift():
    rng = np.random.default_rng(6)
    cfg = ControllerConfig(target_remote_fraction=0.2, window=256)
    ctl = AdaptiveController(cfg)
    b = 32

    def run_phase(easy_frac, batches):
        esc = req = 0
        for _ in range(batches):
            conf = _conf_stream(rng, b, easy_frac)
            cap = ctl.capacity(b)
            t = ctl.t_local
            if t is None:
                k = min(cap, b)
            else:
                k = min(int((conf < t).sum()), cap)
            ctl.observe(conf, k, b)
            esc += k
            req += b
        return esc / req

    run_phase(0.9, 64)                    # settle on the easy mix
    frac_easy = run_phase(0.9, 64)
    assert abs(frac_easy - 0.2) <= 0.03
    run_phase(0.5, 64)                    # drift: many more hard inputs
    frac_hard = run_phase(0.5, 64)
    assert abs(frac_hard - 0.2) <= 0.03
    assert ctl.state.drift_events >= 1
    assert ctl.state.windows > 0


def test_controller_retunes_remote_threshold():
    rng = np.random.default_rng(7)
    cfg = ControllerConfig(target_remote_fraction=0.5, window=64,
                           target_rejection_rate=0.1)
    ctl = AdaptiveController(cfg)
    rconf = rng.uniform(0.0, 1.0, 256)
    for lo in range(0, 256, 32):
        conf = _conf_stream(rng, 32, 0.5)
        ctl.observe(conf, 16, 32, remote_conf=rconf[lo:lo + 32])
    assert ctl.t_remote is not None
    # ~10% of the observed 2nd-level scores fall below the threshold
    assert abs((rconf < ctl.t_remote).mean() - 0.1) < 0.06


def test_psi_detects_shift():
    p = np.array([10, 80, 10, 0], float)
    assert population_stability_index(p, p) == pytest.approx(0.0, abs=1e-6)
    q = np.array([0, 10, 80, 10], float)
    assert population_stability_index(p, q) > 0.25


# ------------------------------------------------- calibration

def _val_set(rng, n=512):
    """Local is right on easy inputs (high conf), remote nearly always."""
    hard = rng.random(n) < 0.4
    local_conf = np.where(hard, rng.uniform(0.2, 0.6, n),
                          rng.uniform(0.7, 1.0, n))
    local_correct = rng.random(n) < np.where(hard, 0.3, 0.95)
    remote_conf = rng.uniform(0.5, 1.0, n)
    remote_correct = rng.random(n) < 0.97
    return local_conf, local_correct, remote_conf, remote_correct


def test_calibration_pareto_and_budget_selection():
    rng = np.random.default_rng(8)
    lc, lok, rc, rok = _val_set(rng)
    pts = sweep_operating_points(lc, lok, rc, rok, grid=17)
    front = pareto_frontier(pts)
    assert 0 < len(front) <= len(pts)
    for p in front:       # no frontier point dominated by another
        assert not any(q.accuracy >= p.accuracy
                       and q.remote_fraction <= p.remote_fraction
                       and q.rejection_rate <= p.rejection_rate
                       and q is not p for q in front)
    point = select_operating_point(front, budget=0.3)
    assert point.remote_fraction <= 0.3 + 1e-9
    # spending budget should never pick something worse than local-only
    local_only = min(front, key=lambda p: p.remote_fraction)
    assert point.accuracy >= local_only.accuracy - 1e-9


def test_calibrate_returns_capacity_and_respects_budget():
    rng = np.random.default_rng(9)
    lc, lok, rc, rok = _val_set(rng)
    point, k, front = calibrate(lc, lok, rc, rok, budget=0.25,
                                batch_size=32, grid=17)
    assert 1 <= k <= 32
    assert k == int(-(-point.remote_fraction * 32 // 1)) or k == 1
    assert point.remote_fraction <= 0.25 + 1e-9
    # cost model consistency
    assert point.cost_per_request == pytest.approx(
        point.remote_fraction * 0.0048)


def test_calibrated_point_reproduces_on_fresh_sample():
    """The selected thresholds transfer: realised remote fraction on an
    i.i.d. fresh draw stays near the calibration estimate."""
    rng = np.random.default_rng(10)
    lc, lok, rc, rok = _val_set(rng, n=2048)
    point, _, _ = calibrate(lc, lok, rc, rok, budget=0.35, batch_size=32)
    lc2, _, _, _ = _val_set(rng, n=2048)
    realised = (lc2 <= point.t_local).mean()
    assert abs(realised - point.remote_fraction) < 0.05


# ------------------------------------------------- trisupervised invariants

def _tri_outputs(rng, n=64):
    conf = lambda: rng.uniform(0, 1, n)
    th = TriThresholds(t_local=rng.uniform(0.3, 0.9),
                       t_edge=rng.uniform(0.3, 0.9),
                       t_remote=rng.uniform(0.3, 0.9))
    preds = [rng.integers(0, 5, n) for _ in range(3)]
    out = trisupervised_batch(
        jnp.asarray(preds[0]), jnp.asarray(conf()),
        jnp.asarray(preds[1]), jnp.asarray(conf()),
        jnp.asarray(preds[2]), jnp.asarray(conf()), th)
    return {k: np.asarray(v) for k, v in out.items()}, preds


def test_trisupervised_each_input_served_by_exactly_one_tier():
    rng = np.random.default_rng(11)
    for _ in range(20):
        out, preds = _tri_outputs(rng)
        src = out["source"]
        assert np.isin(src, [LOCAL, EDGE, REMOTE, REJECTED]).all()
        # the returned prediction comes from the serving tier
        np.testing.assert_array_equal(out["prediction"][src == LOCAL],
                                      preds[0][src == LOCAL])
        np.testing.assert_array_equal(out["prediction"][src == EDGE],
                                      preds[1][src == EDGE])
        remote_served = (src == REMOTE) | (src == REJECTED)
        np.testing.assert_array_equal(out["prediction"][remote_served],
                                      preds[2][remote_served])
        # accepted <-> not rejected
        np.testing.assert_array_equal(out["accepted"], src != REJECTED)


def test_trisupervised_call_set_inclusion():
    """remote_called subset of edge_called; cheaper tiers consulted first."""
    rng = np.random.default_rng(12)
    for _ in range(20):
        out, _ = _tri_outputs(rng)
        edge, remote, src = (out["edge_called"], out["remote_called"],
                             out["source"])
        assert not (remote & ~edge).any()          # remote ⊆ edge
        assert not edge[src == LOCAL].any()        # local-served: no calls
        assert remote[(src == REMOTE) | (src == REJECTED)].all()
        assert not remote[src == EDGE].any()

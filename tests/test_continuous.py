"""Continuous batching (DESIGN.md §11, ISSUE 8): the slot-map serve
loop must keep responses, billing and controller state bitwise-identical
to the fixed-window streaming drain — under adversarial completion
orders, seeded chaos and a live controller — while handing trusted-local
rows back at gate time (no window-drain quantization, no starvation
behind a stuck escalation). Plus the slot-occupancy queue-wait estimate,
the ``/metrics`` scrape endpoint and the ``ServeConfig`` plumbing."""

from __future__ import annotations

import json
import threading
import time
import urllib.error
import urllib.request

import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import (AdaptiveController, ChaosEpisode, ChaosSchedule,
                           ControllerConfig, RemoteTransport,
                           TransportConfig)
from repro.runtime.observability import MetricsRegistry, MetricsServer
from repro.serving.engine import BILLING_FIELDS, CascadeEngine
from repro.serving.policy import ServeConfig
from repro.serving.scheduler import MicrobatchScheduler, Request


def local_apply(x):
    return x + 0.3 * jnp.sin(17.0 * x)


def remote_apply(x):
    return 5.0 * np.asarray(x)


def make_stream(rng, n, c=4, hard_frac=0.5):
    labels = rng.integers(0, c, n)
    x = rng.normal(0, 0.05, (n, c))
    margin = np.where(rng.random(n) < hard_frac, 0.1, 3.0)
    x[np.arange(n), labels] += margin
    return np.float32(x), labels


def quiet_tconf(**kw):
    base = dict(retry_backoff_s=0.0, max_retries=0, breaker_failures=10**6,
                timeout_s=60.0)
    base.update(kw)
    return TransportConfig(**base)


def build(remote=remote_apply, *, batch=8, budget=0.5, depth=4,
          batching="continuous", controller=None, tconf=None,
          transport=None):
    if transport is None:
        transport = RemoteTransport(remote, tconf or quiet_tconf())
    engine = CascadeEngine(local_apply, batch_size=batch,
                           remote_fraction_budget=budget, t_remote=0.0,
                           transport=transport, controller=controller)
    sched = MicrobatchScheduler(engine, fallback=lambda r: -7,
                                pipeline_depth=depth,
                                completion_mode="streaming",
                                batching=batching)
    return sched, engine


def serve_all(sched, xs):
    for i, row in enumerate(xs):
        sched.submit(Request(uid=i, local_input=row, remote_input=row))
    return sched.flush()


def by_uid(responses):
    return {r.uid: (r.prediction, r.source) for r in responses}


def assert_same_accounting(e_a, e_b):
    for f in BILLING_FIELDS:
        assert getattr(e_a.stats, f) == getattr(e_b.stats, f), f
    assert e_a.stats.per_backend == e_b.stats.per_backend


# ------------------------------------------------------ mode plumbing

def test_unknown_batching_rejected():
    _, engine = build()
    with pytest.raises(ValueError, match="batching"):
        MicrobatchScheduler(engine, batching="quantum")
    engine.close()


def test_continuous_requires_streaming_completion():
    _, engine = build()
    with pytest.raises(ValueError, match="streaming"):
        MicrobatchScheduler(engine, completion_mode="fifo",
                            batching="continuous")
    engine.close()


def test_continuous_requires_runtime_path():
    engine = CascadeEngine(local_apply, remote_apply, batch_size=8,
                           remote_fraction_budget=0.5, t_remote=0.0)
    with pytest.raises(ValueError, match="runtime"):
        MicrobatchScheduler(engine, completion_mode="streaming",
                            batching="continuous")


def test_serveconfig_batching_validation():
    with pytest.raises(ValueError, match="batching"):
        ServeConfig(batch_size=8, batching="quantum")
    with pytest.raises(ValueError, match="streaming"):
        ServeConfig(batch_size=8, batching="continuous",
                    completion_mode="fifo")
    with pytest.raises(ValueError, match="fused"):
        ServeConfig(batch_size=8, fused=True, batching="continuous",
                    completion_mode="streaming")
    cfg = ServeConfig(batch_size=8, batching="continuous",
                      completion_mode="streaming")
    assert cfg.batching == "continuous"


# ------------------------------------- continuous == window identity

def test_continuous_matches_window_static_thresholds():
    """Slot-map admission + early emit must never change what the
    cascade answers or charges: same stream, same cohorts, bitwise-
    identical responses and billing vs the fixed-window drain."""
    rng = np.random.default_rng(1)
    xs, _ = make_stream(rng, 64)

    s_win, e_win = build(batching="window")
    s_con, e_con = build(batching="continuous")
    r_win = serve_all(s_win, xs)
    r_con = serve_all(s_con, xs)
    assert sorted(r.uid for r in r_con) == list(range(64))
    assert by_uid(r_win) == by_uid(r_con)
    assert_same_accounting(e_win, e_con)
    e_win.close()
    e_con.close()


def test_continuous_matches_window_adversarial_completion_order():
    """Early windows complete LAST: later cohorts' escalations resolve
    and hand back first, slots churn out of submission order — answers
    and billing must still match the window drain bit for bit."""
    rng = np.random.default_rng(2)
    xs, _ = make_stream(rng, 64)

    def make_reordering():
        calls = {"n": 0}
        lock = threading.Lock()

        def reordering_remote(x):
            with lock:
                calls["n"] += 1
                i = calls["n"]
            time.sleep(0.03 * max(0, 4 - i))    # first windows slowest
            return remote_apply(x)
        return reordering_remote

    s_win, e_win = build(make_reordering(), batching="window")
    s_con, e_con = build(make_reordering(), batching="continuous")
    r_win = serve_all(s_win, xs)
    r_con = serve_all(s_con, xs)
    assert by_uid(r_win) == by_uid(r_con)
    assert_same_accounting(e_win, e_con)
    e_win.close()
    e_con.close()


def test_continuous_with_live_controller_matches_window():
    """A live controller couples acceptance thresholds to commit order.
    The continuous loop keeps the depth-window admission bound in
    controller mode, so the begin/commit interleaving — and hence every
    threshold snapshot — reproduces the window drain exactly."""
    rng = np.random.default_rng(3)
    xs, _ = make_stream(rng, 96)

    def make(batching):
        ctl = AdaptiveController(ControllerConfig(
            target_remote_fraction=0.3, window=32))
        return build(batching=batching, controller=ctl)

    s_win, e_win = make("window")
    s_con, e_con = make("continuous")
    r_win = serve_all(s_win, xs)
    r_con = serve_all(s_con, xs)
    assert by_uid(r_win) == by_uid(r_con)
    assert_same_accounting(e_win, e_con)
    assert e_win.controller.state == e_con.controller.state
    e_win.close()
    e_con.close()


def test_continuous_matches_window_under_seeded_chaos():
    """A seeded brownout faults windows by call COUNT; with a single
    transport worker the count order is the submission order in both
    modes, so the same cohorts fault the same way — REJECTED/fallback
    rows and billing must stay identical."""
    rng = np.random.default_rng(4)
    xs, _ = make_stream(rng, 64, hard_frac=0.8)

    def run(batching):
        t = RemoteTransport(remote_apply,
                            quiet_tconf(max_concurrent=1))
        ChaosSchedule([ChaosEpisode("brownout", 0.0, 1e12, rate=0.5,
                                    name="b")],
                      seed=9).wrap_transport(t, "only")
        sched, engine = build(batching=batching, transport=t)
        resp = serve_all(sched, xs)
        engine.close()
        return resp, engine

    r_win, e_win = run("window")
    r_con, e_con = run("continuous")
    assert by_uid(r_win) == by_uid(r_con)
    assert_same_accounting(e_win, e_con)
    assert e_win.stats.transport_failures > 0       # chaos actually bit
    assert {r.source for r in r_win} >= {"local", "fallback"}


def test_forced_early_emit_matches_window_and_sweeps():
    """early_emit=True forces the in-kernel io_callback path even on
    CPU (from_config arms it via "auto" only where dispatch overlaps —
    TPU). The callback-fed host half must produce identical results to
    the window drain, every dispatch must land a callback, and commits
    must sweep the stored triples."""
    rng = np.random.default_rng(8)
    xs, _ = make_stream(rng, 48)

    def make(batching, early_emit):
        t = RemoteTransport(remote_apply, quiet_tconf())
        engine = CascadeEngine(local_apply, batch_size=8,
                               remote_fraction_budget=0.5, t_remote=0.0,
                               transport=t, early_emit=early_emit)
        sched = MicrobatchScheduler(engine, fallback=lambda r: -7,
                                    pipeline_depth=4,
                                    completion_mode="streaming",
                                    batching=batching)
        return sched, engine

    s_win, e_win = make("window", early_emit=False)
    s_con, e_con = make("continuous", early_emit=True)
    assert e_con.early_emit and not e_win.early_emit
    r_win = serve_all(s_win, xs)
    r_con = serve_all(s_con, xs)
    assert by_uid(r_win) == by_uid(r_con)
    assert_same_accounting(e_win, e_con)
    assert e_con._gate_emits == 48 // 8     # one callback per dispatch
    assert e_con._gate_results == {}        # swept at commit
    e_win.close()
    e_con.close()


def test_continuous_fused_local_head_matches_window():
    """The fused local-head->gate path (kernels/fused_head_gate) drives
    the engine's local step whenever local_apply is a FusedLocalHead;
    slot-map scheduling on top of it must still match the window drain
    bitwise."""
    from repro.kernels.fused_head_gate.ops import FusedLocalHead
    rng = np.random.default_rng(7)
    xs, _ = make_stream(rng, 48)
    w = jnp.asarray(rng.normal(0, 0.5, (4, 4)), jnp.float32)
    head = FusedLocalHead(trunk=lambda x: x, w=w,
                          bias=jnp.zeros((4,), jnp.float32))

    def make(batching):
        t = RemoteTransport(remote_apply, quiet_tconf())
        engine = CascadeEngine(head, batch_size=8,
                               remote_fraction_budget=0.5, t_remote=0.0,
                               transport=t)
        sched = MicrobatchScheduler(engine, fallback=lambda r: -7,
                                    pipeline_depth=4,
                                    completion_mode="streaming",
                                    batching=batching)
        return sched, engine

    s_win, e_win = make("window")
    s_con, e_con = make("continuous")
    r_win = serve_all(s_win, xs)
    r_con = serve_all(s_con, xs)
    assert by_uid(r_win) == by_uid(r_con)
    assert_same_accounting(e_win, e_con)
    e_win.close()
    e_con.close()


# ------------------------------------------- the point of continuous

def test_trusted_locals_hand_back_while_escalation_stuck():
    """Slot starvation guard: one cohort's escalation parked on a slow
    remote must not wedge later cohorts — their trusted-local rows join
    free slots, clear the gate and hand back immediately."""
    remote_lat = 0.3
    calls = {"n": 0}
    lock = threading.Lock()

    def slow_first_remote(x):
        with lock:
            calls["n"] += 1
            i = calls["n"]
        time.sleep(remote_lat if i == 1 else 0.0)
        return remote_apply(x)

    rng = np.random.default_rng(5)
    # first cohort: half hard (escalates, rides the stuck remote);
    # everything after: easy, trusted-local
    xs_hard, _ = make_stream(rng, 8, hard_frac=0.5)
    xs_easy, _ = make_stream(rng, 40, hard_frac=0.0)
    xs = np.concatenate([xs_hard, xs_easy])

    sched, engine = build(slow_first_remote, batch=8, depth=4)
    # warm the jit cache out of band, then reset accounting: measured
    # latencies must reflect serving, not first-call compilation
    engine.serve({"local": xs[:8], "remote": xs[:8]})
    engine.stats = type(engine.stats)()
    calls["n"] = 0
    responses = serve_all(sched, xs)
    assert sorted(r.uid for r in responses) == list(range(48))
    local = [r for r in responses if r.source == "local"]
    esc = [r for r in responses if r.source != "local"]
    # capacity-k: every cohort escalates its bottom half, but only the
    # FIRST cohort's escalations ride the stuck remote call
    stuck = [r for r in esc if r.uid < 8]
    assert stuck and min(r.latency_s for r in stuck) >= remote_lat
    # every trusted-local row beat the stuck remote home — including
    # rows submitted AFTER the stuck cohort
    assert max(r.latency_s for r in local) < remote_lat
    assert sched.first_response_s < remote_lat
    # slot ledger reconciles: every admitted row joined and left
    assert sched._slots.joins == sched._slots.leaves == 48
    assert sched._slots.occupied == 0
    assert 0 < sched._slots.peak <= sched._slots.capacity
    engine.close()


def test_queue_wait_estimate_prices_slot_occupancy():
    """Continuous mode prices admission against slot occupancy amortized
    over the pipeline width; window mode prices whole windows ahead."""
    s_con, e_con = build(batch=8, depth=4)
    s_win, e_win = build(batch=8, depth=4, batching="window")
    for e in (e_con, e_win):
        e.stats.window_service_ema_s = 0.1

    # idle slot map: one window's EMA, regardless of queue depth < batch
    assert s_con._queue_wait_estimate(0) == pytest.approx(0.1)
    # 24 occupied slots + 8 queued = 4 windows ahead, amortized over 4
    s_con._slots.join(24)
    assert s_con._queue_wait_estimate(8) == pytest.approx(
        0.1 * (1.0 + (8 + 24) // 8 / 4))
    # window mode: whole windows ahead of the row, plus its own
    assert s_win._queue_wait_estimate(0) == pytest.approx(0.1)
    assert s_win._queue_wait_estimate(24) == pytest.approx(0.4)
    s_con._slots.leave(24)
    e_con.close()
    e_win.close()


def test_slot_map_telemetry_ema():
    from repro.serving.scheduler import _SlotMap
    sm = _SlotMap(32)
    assert sm.free == 32
    sm.join(16)
    assert sm.free == 16 and sm.peak == 16
    assert 0.0 < sm.occupancy_ema <= 0.5
    sm.leave(16)
    assert sm.occupied == 0 and sm.leaves == 16


# --------------------------------------------- /metrics scrape endpoint

def test_metrics_server_serves_prometheus_and_json():
    reg = MetricsRegistry()
    reg.counter("cascade_requests_total").inc(42)
    with MetricsServer(reg, port=0) as srv:
        assert srv.port > 0
        body = urllib.request.urlopen(srv.url, timeout=5).read().decode()
        assert "cascade_requests_total 42" in body

        js = urllib.request.urlopen(
            f"http://{srv.host}:{srv.port}/metrics.json",
            timeout=5).read()
        snap = json.loads(js)
        assert snap["counters"]["cascade_requests_total"] == 42

        ok = urllib.request.urlopen(
            f"http://{srv.host}:{srv.port}/healthz", timeout=5).read()
        assert ok == b"ok\n"

        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                f"http://{srv.host}:{srv.port}/nope", timeout=5)
    # closed: the port no longer accepts connections
    with pytest.raises(OSError):
        urllib.request.urlopen(srv.url, timeout=0.5)


def test_metrics_server_live_engine_counters():
    """End to end: a continuous serve loop's commit-time counters are
    scrapeable over HTTP while the engine is still open."""
    from repro.runtime import Observability
    rng = np.random.default_rng(6)
    xs, _ = make_stream(rng, 16)
    sched, engine = build()
    Observability.enabled().install(engine)
    serve_all(sched, xs)
    with MetricsServer(engine.observability.metrics, port=0) as srv:
        body = urllib.request.urlopen(srv.url, timeout=5).read().decode()
    assert "cascade_requests_total 16" in body
    engine.close()

"""Per-request policy API + ServeConfig facade (DESIGN.md §8): policy
edge cases (deadline shorter than the local forward, escalation="never"
under an untrusted gate, cost_cap=0 forcing local-only, mixed-policy
windows preserving bitwise billing identity), deadline-vs-EMA downgrades,
constraint-aware + weighted routing, policy-aware window packing, the
calibration-table escalation prior, and Response billing attribution."""

from __future__ import annotations

import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import (AdaptiveController, ControllerConfig,
                           RemoteBackend, RemoteResponseCache, RemoteRouter,
                           RouteConstraint, TransportConfig,
                           fit_escalation_prior)
from repro.serving import RemoteSpec, RequestPolicy, ServeConfig
from repro.serving.engine import BILLING_FIELDS
from repro.serving.policy import (CACHED, DEADLINE_LOCAL, LOCAL,
                                  POLICY_LOCAL, REJECTED, REMOTE)
from repro.serving.scheduler import Request


def local_apply(x):
    return x + 0.3 * jnp.sin(17.0 * x)


def remote_apply(x):
    return 5.0 * np.asarray(x)


def make_stream(rng, n, c=4, hard_frac=0.5):
    labels = rng.integers(0, c, n)
    x = rng.normal(0, 0.05, (n, c))
    margin = np.where(rng.random(n) < hard_frac, 0.1, 3.0)
    x[np.arange(n), labels] += margin
    return np.float32(x), labels


def quiet_tconf(**kw):
    base = dict(retry_backoff_s=0.0, max_retries=0, breaker_failures=10**6,
                timeout_s=60.0)
    base.update(kw)
    return TransportConfig(**base)


def mk_config(**kw):
    base = dict(batch_size=8, remote_fraction_budget=0.5, t_remote=0.0,
                pipeline_depth=2, cache_size=0, transport=quiet_tconf())
    base.update(kw)
    return ServeConfig(**base)


def build(remote=remote_apply, *, router=None, cache=None, prior=None,
          controller=None, **cfg_kw):
    cfg = mk_config(**cfg_kw)
    kw = {}
    if router is not None:
        kw["transport"] = router
        remote = None
    if cache is not None:
        kw["cache"] = cache
    if controller is not None:
        kw["controller"] = controller
    engine, sched = cfg.build(local_apply, remote, fallback=lambda r: -7,
                              prior=prior, **kw)
    return sched, engine


def serve_all(sched, xs, policies=None):
    for i, row in enumerate(xs):
        sched.submit(Request(uid=i, local_input=row, remote_input=row,
                             policy=policies[i] if policies else None))
    return sched.flush()


def by_uid(responses):
    return {r.uid: (r.prediction, r.source) for r in responses}


def assert_same_accounting(e_a, e_b):
    for f in BILLING_FIELDS:
        assert getattr(e_a.stats, f) == getattr(e_b.stats, f), f
    assert e_a.stats.per_backend == e_b.stats.per_backend


# ------------------------------------------------- RequestPolicy object

def test_request_policy_validation():
    with pytest.raises(ValueError):
        RequestPolicy(escalation="sometimes")
    with pytest.raises(ValueError):
        RequestPolicy(on_miss="retry")
    with pytest.raises(ValueError):
        RequestPolicy(deadline_s=-1.0)
    with pytest.raises(ValueError):
        RequestPolicy(cost_cap=-0.01)
    assert RequestPolicy().is_default
    assert not RequestPolicy(deadline_s=1.0).is_default
    assert not RequestPolicy(escalation="never").is_default


def test_serve_config_overrides():
    cfg = ServeConfig().with_overrides([
        "pipeline_depth=8", "completion_mode=streaming",
        "transport.timeout_s=1.5", "default_policy.deadline_s=0.5",
        "remotes=cheap:0.002:0.4;fast:0.008:0.1",
        "route_policy=weighted", "cost_budget=none", "adaptive=true",
    ])
    assert cfg.pipeline_depth == 8
    assert cfg.completion_mode == "streaming"
    assert cfg.transport.timeout_s == 1.5
    assert cfg.default_policy.deadline_s == 0.5
    assert cfg.remotes == (RemoteSpec("cheap", 0.002, 0.4),
                           RemoteSpec("fast", 0.008, 0.1))
    assert cfg.route_policy == "weighted" and cfg.adaptive
    assert ServeConfig().with_overrides(["remotes=none"]).remotes == ()
    with pytest.raises(ValueError):
        ServeConfig(fused=True, remotes=(RemoteSpec("r"),))
    with pytest.raises(ValueError):
        ServeConfig().with_overrides(["no_such_field=1"])
    # non-scalar fields demand nested overrides — a raw string must be
    # rejected at parse time, not stored to blow up at build time
    with pytest.raises(ValueError):
        ServeConfig().with_overrides(["default_policy=fast"])
    with pytest.raises(ValueError):
        ServeConfig().with_overrides(["transport=x"])
    with pytest.raises(ValueError):
        ServeConfig().with_overrides(["cost=0.5"])
    with pytest.raises(ValueError):
        ServeConfig().with_overrides(["badpair"])
    with pytest.raises(ValueError):
        ServeConfig(route_policy="psychic")
    with pytest.raises(ValueError):
        ServeConfig(fused=True, pipeline_depth=4)
    with pytest.raises(ValueError):
        ServeConfig(fused=True,
                    default_policy=RequestPolicy(deadline_s=1.0))


# ----------------------------------------- policy edge-case enforcement

def test_escalation_never_with_untrusted_gate_stays_local():
    rng = np.random.default_rng(0)
    xs, _ = make_stream(rng, 8, hard_frac=1.0)      # every gate untrusted
    sched, engine = build(remote_fraction_budget=1.0)
    resp = serve_all(sched, xs, [RequestPolicy(escalation="never")] * 8)
    assert {r.source for r in resp} == {"local"}
    assert {r.disposition for r in resp} == {POLICY_LOCAL}
    assert engine.stats.escalations == 0
    assert engine.stats.total_cost == 0.0
    assert all(r.cost == 0.0 and r.backend is None for r in resp)
    engine.close()


def test_escalation_always_with_trusted_gate_escalates_and_bills():
    rng = np.random.default_rng(1)
    xs, _ = make_stream(rng, 8, hard_frac=0.0)      # every gate trusted
    sched, engine = build(remote_fraction_budget=1.0)
    resp = serve_all(sched, xs, [RequestPolicy(escalation="always")] * 8)
    assert {r.source for r in resp} == {"remote"}
    assert {r.disposition for r in resp} == {REMOTE}
    assert engine.stats.remote_calls == 8
    assert all(r.backend == "remote" and r.cost > 0 for r in resp)
    np.testing.assert_allclose(sum(r.cost for r in resp),
                               engine.stats.total_cost)
    engine.close()


def test_cost_cap_zero_forces_local_only():
    rng = np.random.default_rng(2)
    xs, _ = make_stream(rng, 8, hard_frac=1.0)
    sched, engine = build(remote_fraction_budget=1.0)
    resp = serve_all(sched, xs, [RequestPolicy(cost_cap=0.0)] * 8)
    assert {r.source for r in resp} == {"local"}
    assert {r.disposition for r in resp} == {POLICY_LOCAL}
    assert engine.stats.total_cost == 0.0 and engine.stats.remote_calls == 0
    engine.close()


def test_deadline_shorter_than_local_forward_still_served():
    """A deadline no serving mode could meet must not drop or wedge the
    request: it downgrades to the local prediction (DEADLINE_LOCAL), or
    the REJECTED path with on_miss="reject"."""
    rng = np.random.default_rng(3)
    xs, _ = make_stream(rng, 16, hard_frac=1.0)
    pol = ([RequestPolicy(deadline_s=1e-9)] * 8
           + [RequestPolicy(deadline_s=1e-9, on_miss="reject")] * 8)
    sched, engine = build(remote_fraction_budget=1.0)
    resp = serve_all(sched, xs, pol)
    assert sorted(r.uid for r in resp) == list(range(16))   # zero drops
    down = [r for r in resp if r.uid < 8]
    rej = [r for r in resp if r.uid >= 8]
    assert {r.disposition for r in down} == {DEADLINE_LOCAL}
    assert {r.source for r in down} == {"local"}
    assert {r.disposition for r in rej} == {REJECTED}
    assert {r.source for r in rej} == {"fallback"}
    assert all(r.prediction == -7 for r in rej)     # scheduler fallback
    assert engine.stats.total_cost == 0.0
    # policy-rejected rows count as rejected, never as escalations: the
    # billing invariant stays exact
    st = engine.stats
    assert st.escalations == st.remote_calls + st.cache_hits \
        + st.transport_failures
    assert st.rejected == 8
    engine.close()


def test_deadline_downgrade_uses_measured_latency_ema():
    """A backend with a fast modelled prior but slow MEASURED latency
    must be treated as slow: the EMA, not the spec sheet, decides."""
    rng = np.random.default_rng(4)
    xs, _ = make_stream(rng, 8, hard_frac=1.0)
    backend = RemoteBackend("only", remote_apply, quiet_tconf(),
                            latency_s=0.001)        # optimistic prior
    router = RemoteRouter([backend])
    sched, engine = build(router=router, remote_fraction_budget=1.0)
    pol = [RequestPolicy(deadline_s=0.2)] * 8
    for _ in range(8):                  # measured reality: 0.5 s windows
        backend.stats.record_latency(0.5)
    resp = serve_all(sched, xs, pol)
    assert {r.disposition for r in resp} == {DEADLINE_LOCAL}
    assert engine.stats.remote_calls == 0
    engine.close()


def test_feasible_deadline_escalates():
    rng = np.random.default_rng(5)
    xs, _ = make_stream(rng, 8, hard_frac=1.0)
    sched, engine = build(remote_fraction_budget=1.0,
                          remotes=(RemoteSpec("remote", None, 0.0),))
    resp = serve_all(sched, xs, [RequestPolicy(deadline_s=60.0)] * 8)
    assert {r.disposition for r in resp} == {REMOTE}
    assert engine.stats.remote_calls == 8
    engine.close()


# ------------------------------------------------ policy-aware routing

def test_routing_hint_prefers_named_backend():
    rng = np.random.default_rng(6)
    xs, _ = make_stream(rng, 8, hard_frac=1.0)
    router = RemoteRouter([
        RemoteBackend("a", remote_apply, quiet_tconf(),
                      cost_per_request=0.001),
        RemoteBackend("b", remote_apply, quiet_tconf(),
                      cost_per_request=0.009),
    ])
    sched, engine = build(router=router, remote_fraction_budget=1.0)
    resp = serve_all(sched, xs, [RequestPolicy(routing_hint="b")] * 8)
    assert {r.backend for r in resp} == {"b"}
    assert engine.stats.per_backend["b"].remote_calls == 8
    np.testing.assert_allclose(engine.stats.total_cost, 8 * 0.009)
    engine.close()


def test_cost_cap_steers_routing_to_affordable_backend():
    rng = np.random.default_rng(7)
    xs, _ = make_stream(rng, 8, hard_frac=1.0)
    router = RemoteRouter([          # preferred order: expensive first
        RemoteBackend("fast", remote_apply, quiet_tconf(),
                      cost_per_request=0.009),
        RemoteBackend("cheap", remote_apply, quiet_tconf(),
                      cost_per_request=0.001),
    ])
    sched, engine = build(router=router, remote_fraction_budget=1.0)
    resp = serve_all(sched, xs, [RequestPolicy(cost_cap=0.002)] * 8)
    assert {r.backend for r in resp} == {"cheap"}
    assert all(r.cost <= 0.002 for r in resp)
    assert "fast" not in engine.stats.per_backend
    engine.close()


def test_route_constraint_admits():
    b = RemoteBackend("x", remote_apply, quiet_tconf(),
                      cost_per_request=0.005, latency_s=0.3)
    assert RouteConstraint().admits(b)
    assert RouteConstraint(max_cost=0.005).admits(b)
    assert not RouteConstraint(max_cost=0.004).admits(b)
    assert RouteConstraint(max_latency_s=0.3).admits(b)
    assert not RouteConstraint(max_latency_s=0.2).admits(b)
    unpriced = RemoteBackend("y", remote_apply, quiet_tconf())
    assert RouteConstraint(max_cost=0.001).admits(unpriced)
    assert not RouteConstraint(max_cost=0.001,
                               default_cost=0.0048).admits(unpriced)


def test_weighted_policy_spreads_by_inflight():
    gate = threading.Event()

    def blocking_remote(x):
        gate.wait(5.0)
        return remote_apply(x)

    b1 = RemoteBackend("b1", blocking_remote, quiet_tconf(),
                       cost_per_request=0.004)
    b2 = RemoteBackend("b2", blocking_remote, quiet_tconf(),
                       cost_per_request=0.004)
    router = RemoteRouter([b1, b2], policy="weighted")
    first = router.pick()
    assert first is b1                  # tie -> registration order
    fut = b1.submit(np.zeros((2, 4), np.float32))
    assert b1.inflight == 1
    assert router.pick() is b2          # least-loaded of equal price
    gate.set()
    fut.result()
    assert b1.inflight == 0             # released on completion
    assert router.pick() is b1
    # load only breaks ties WITHIN a price class: a busy cheap backend
    # still beats an idle pricier one
    b3 = RemoteBackend("b3", blocking_remote, quiet_tconf(),
                       cost_per_request=0.009)
    router2 = RemoteRouter([b1, b3], policy="weighted")
    gate.clear()
    fut = b1.submit(np.zeros((2, 4), np.float32))
    assert router2.pick() is b1
    gate.set()
    fut.result()
    b1.shutdown()
    b2.shutdown()
    b3.shutdown()
    assert "weighted" not in ("primary-failover", "cheapest-available",
                              "latency-ema")        # genuinely new policy


# --------------------------------------- bitwise identity + accounting

def test_mixed_policy_window_keeps_bitwise_billing_identity():
    """A window mixing unconstraining policies with unpolicied rows must
    answer and bill exactly like the fully-unpolicied path."""
    rng = np.random.default_rng(8)
    xs, _ = make_stream(rng, 48)
    relaxed = RequestPolicy(deadline_s=1e6)         # policied, no bite
    pols = [relaxed if i % 2 == 0 else None for i in range(48)]

    s_pol, e_pol = build()
    s_raw, e_raw = build()
    r_pol = serve_all(s_pol, xs, pols)
    r_raw = serve_all(s_raw, xs)
    assert by_uid(r_pol) == by_uid(r_raw)
    assert_same_accounting(e_pol, e_raw)
    e_pol.close()
    e_raw.close()


def test_policied_streaming_matches_fifo_accounting():
    rng = np.random.default_rng(9)
    xs, _ = make_stream(rng, 64)
    pols = [RequestPolicy(deadline_s=1e-9) if i % 3 == 0
            else RequestPolicy(escalation="always") if i % 3 == 1
            else None for i in range(64)]

    def run(mode):
        sched, engine = build(completion_mode=mode, pipeline_depth=4)
        resp = serve_all(sched, xs, list(pols))
        engine.close()
        return resp, engine

    r_f, e_f = run("fifo")
    r_s, e_s = run("streaming")
    assert by_uid(r_f) == by_uid(r_s)
    assert_same_accounting(e_f, e_s)


def test_response_attribution_cache_hit_and_costs_sum():
    rng = np.random.default_rng(10)
    xs, _ = make_stream(rng, 8, hard_frac=1.0)
    cache = RemoteResponseCache(64)
    sched, engine = build(remote_fraction_budget=1.0, cache=cache)
    r1 = serve_all(sched, xs)                   # all miss -> billed
    assert {r.disposition for r in r1} == {REMOTE}
    for i, row in enumerate(xs):                # identical content -> hits
        sched.submit(Request(uid=100 + i, local_input=row,
                             remote_input=row))
    r2 = sched.flush()
    assert {r.disposition for r in r2} == {CACHED}
    assert all(r.cost == 0.0 and r.backend == "remote" for r in r2)
    total = sum(r.cost for r in r1) + sum(r.cost for r in r2)
    np.testing.assert_allclose(total, engine.stats.total_cost)
    engine.close()


def test_forced_reject_rows_never_count_as_cache_hits():
    """A window mixing a genuine cache hit with a policy-REJECTED row:
    the forced row must not inflate per-backend cache-hit attribution
    (Σ per-backend hits == aggregate cache_hits)."""
    rng = np.random.default_rng(16)
    xs, _ = make_stream(rng, 4, hard_frac=1.0)
    cache = RemoteResponseCache(64)
    sched, engine = build(remote_fraction_budget=1.0, cache=cache,
                          batch_size=8)
    serve_all(sched, xs)                            # fill the cache
    fresh, _ = make_stream(rng, 4, hard_frac=1.0)
    mixed = np.concatenate([xs, fresh])             # 4 hits + 4 forced
    pols = [None] * 4 + [RequestPolicy(cost_cap=0.0,
                                       on_miss="reject")] * 4
    for i, row in enumerate(mixed):
        sched.submit(Request(uid=100 + i, local_input=row,
                             remote_input=row, policy=pols[i]))
    resp = sched.flush()
    st = engine.stats
    assert st.cache_hits == 4
    assert sum(u.cache_hits for u in st.per_backend.values()) == 4
    assert st.escalations == st.remote_calls + st.cache_hits \
        + st.transport_failures
    assert {r.disposition for r in resp if r.uid >= 104} == {REJECTED}
    engine.close()


def test_window_constraint_recomputes_remaining_budget():
    """The routing constraint's latency ceiling is the remaining
    deadline budget AT THE ROUTING DECISION, not a snapshot from the
    host half: after pipeline residency (e.g. an (unrouted) replay at
    drain time) the burnt-down — possibly expired — budget applies."""
    from repro.serving.engine import _InFlight

    t = {"now": 0.0}
    cfg = mk_config(remote_fraction_budget=1.0)
    engine = cfg.build_engine(local_apply, remote_apply,
                              clock=lambda: t["now"])
    fl = _InFlight(seq=1, t0=0.0, b=8, real=8, asynchronous=True,
                   capacity=8)
    assert engine._window_constraint(fl) is None
    fl.constraint = RouteConstraint(max_cost=0.01, default_cost=0.004)
    assert engine._window_constraint(fl).max_latency_s is None
    fl.abs_deadline = 5.0                   # enqueue-anchored absolute
    t["now"] = 1.0
    assert engine._window_constraint(fl).max_latency_s == 4.0
    t["now"] = 10.0                         # expired mid-pipeline
    c = engine._window_constraint(fl)
    assert c.max_latency_s == -5.0
    fast = RemoteBackend("fast", remote_apply, quiet_tconf(),
                         latency_s=0.0)
    assert not c.admits(fast)               # nobody can serve an expired SLA
    engine.close()


def test_fused_path_rejects_policies():
    cfg = ServeConfig(batch_size=8, remote_fraction_budget=0.5,
                      t_remote=0.0, fused=True)
    engine, sched = cfg.build(local_apply, lambda x: 5.0 * jnp.asarray(x))
    rng = np.random.default_rng(11)
    xs, _ = make_stream(rng, 8)
    with pytest.raises(RuntimeError):
        serve_all(sched, xs, [RequestPolicy(deadline_s=1.0)] * 8)
    # unpolicied fused serving still works
    resp = serve_all(sched, xs)
    assert len(resp) == 8 and {r.disposition for r in resp} <= {
        LOCAL, REMOTE, REJECTED}


# ------------------------------------------------ policy window packing

def test_packing_separates_hot_and_cold_and_drains_cold_first():
    rng = np.random.default_rng(12)
    xs, _ = make_stream(rng, 32, hard_frac=0.5)
    margins = np.sort(xs, axis=1)
    hard = (margins[:, -1] - margins[:, -2]) < 1.0
    prior = lambda req: float(
        np.sort(req.local_input)[-1] - np.sort(req.local_input)[-2] < 1.0)
    sched, engine = build(packing="policy", prior=prior,
                          pipeline_depth=4)
    resp = serve_all(sched, xs)
    assert sorted(r.uid for r in resp) == list(range(32))
    ps = sched.packing_stats
    assert ps["mixed"] == 0
    assert ps["cold"] > 0 and ps["hot"] > 0
    assert ps["windows"] == ps["cold"] + ps["hot"]
    # FIFO drain: the first response comes from a COLD window
    assert not hard[resp[0].uid]
    engine.close()


def test_packing_classifies_policy_pinned_rows_cold():
    """Rows that can never go remote (tight deadline) must land in cold
    windows even when the prior calls them likely-escalating."""
    rng = np.random.default_rng(13)
    xs, _ = make_stream(rng, 16, hard_frac=1.0)     # all look hot
    pols = [RequestPolicy(deadline_s=1e-9) if i < 8 else None
            for i in range(16)]
    sched, engine = build(packing="policy", prior=lambda req: 1.0,
                          remote_fraction_budget=1.0)
    resp = serve_all(sched, xs, pols)
    ps = sched.packing_stats
    assert ps["cold"] == 1 and ps["hot"] == 1 and ps["mixed"] == 0
    tight = [r for r in resp if r.uid < 8]
    assert {r.disposition for r in tight} == {DEADLINE_LOCAL}
    engine.close()


def test_packing_requires_runtime_path():
    with pytest.raises(ValueError):
        ServeConfig(fused=True, packing="policy")


# -------------------------------------- calibration-table prior + ctl

def test_fit_escalation_prior_matches_empirical_rates():
    rng = np.random.default_rng(14)
    scores = rng.uniform(0, 1, 4096)
    escalated = scores < 0.3            # low proxy score -> escalates
    prior = fit_escalation_prior(scores, escalated, bins=8)
    assert prior(0.05) > 0.9
    assert prior(0.9) < 0.1
    batch = prior.batch(np.array([0.05, 0.9]))
    assert batch[0] > 0.9 and batch[1] < 0.1
    with pytest.raises(ValueError):
        fit_escalation_prior(np.array([]), np.array([]))
    # constant proxy degrades to the global rate
    flat = fit_escalation_prior(np.ones(64), np.arange(64) < 16)
    np.testing.assert_allclose(flat(1.0), 0.25)


def test_controller_policy_blocked_excludes_ineligible_rows():
    ctl = AdaptiveController(ControllerConfig(target_remote_fraction=0.2,
                                              window=64))
    conf = np.linspace(0, 1, 32)
    # half of every batch is policy-blocked: the realised fraction must
    # be measured over the eligible 16 rows, not all 32
    for _ in range(4):
        ctl.observe(conf, escalated=4, requests=32, policy_blocked=16)
    # 4 batches x 16 eligible rows = one 64-row control window; the
    # realised fraction is 16/64 over ELIGIBLE rows (it would read
    # 16/128 if blocked rows were counted)
    assert ctl.state.windows == 1
    np.testing.assert_allclose(ctl.state.ema_fraction, 16 / 64)


# -------------------------------------------------- enqueue-based SLA

def test_deadline_anchor_is_enqueue_time():
    """The deadline budget starts at submit(): a request that sat in the
    queue long enough has no remaining budget and must downgrade even
    though the round trip alone would have fit."""
    rng = np.random.default_rng(15)
    xs, _ = make_stream(rng, 8, hard_frac=1.0)
    sched, engine = build(remote_fraction_budget=1.0,
                          remotes=(RemoteSpec("remote", None, 0.05),))
    pol = [RequestPolicy(deadline_s=0.2)] * 8
    for i, row in enumerate(xs):
        sched.submit(Request(uid=i, local_input=row, remote_input=row,
                             policy=pol[i]))
    time.sleep(0.3)                     # burn the budget in the queue
    resp = sched.flush()
    assert {r.disposition for r in resp} == {DEADLINE_LOCAL}
    assert all(r.latency_s >= 0.3 for r in resp)    # enqueue -> hand-back
    engine.close()

"""Per-architecture smoke tests (assignment deliverable f).

Each assigned architecture is instantiated as a REDUCED variant of the same
family (2 layers, d_model<=256, <=4 experts) and runs a real forward /
train step / prefill / decode step on CPU, asserting output shapes and the
absence of NaNs. The FULL configs are exercised only via the dry-run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.configs import INPUT_SHAPES, get_config, list_archs, shape_applicable
from repro.models import transformer as T
from repro.models.frontend import frontend_embeddings
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state

ARCHS = list_archs()
B, L = 2, 64


def make_batch(cfg, b=B, t=L):
    if cfg.takes_embeddings and cfg.family == "vlm":
        half = t // 2
        return {"embeds": frontend_embeddings(cfg, b, half),
                "tokens": jnp.ones((b, half), jnp.int32)}
    if cfg.takes_embeddings:
        batch = {"embeds": frontend_embeddings(cfg, b, t)}
    else:
        batch = {"tokens": jnp.ones((b, t), jnp.int32)}
    if cfg.is_encoder:
        batch["labels"] = jnp.zeros((b, t), jnp.int32)
    return batch


@pytest.fixture(scope="module")
def arch_setup():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch).reduced()
            params = T.init_params(cfg, jax.random.PRNGKey(0))
            cache[arch] = (cfg, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    assert cfg.name == arch
    assert cfg.num_layers >= 24
    assert cfg.vocab_size > 0
    assert cfg.citation


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch, arch_setup):
    cfg, params = arch_setup(arch)
    batch = make_batch(cfg)
    loss, metrics = T.loss_fn(cfg, params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss={loss}"
    assert 0.0 <= float(metrics["acc"]) <= 1.0


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_updates_params(arch, arch_setup):
    cfg, params = arch_setup(arch)
    batch = make_batch(cfg)
    (loss, _), grads = jax.value_and_grad(
        lambda p: T.loss_fn(cfg, p, batch), has_aux=True)(params)
    gnorms = [float(jnp.linalg.norm(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads)]
    assert all(jnp.isfinite(g) for g in gnorms), arch
    assert any(g > 0 for g in gnorms), f"{arch}: all-zero grads"
    opt = init_opt_state(params)
    new_params, _, stats = adamw_update(AdamWConfig(), params, grads, opt)
    assert bool(jnp.isfinite(stats["grad_norm"]))
    changed = jax.tree.map(
        lambda a, b: bool(jnp.any(a.astype(jnp.float32)
                                  != b.astype(jnp.float32))),
        params, new_params)
    assert any(jax.tree.leaves(changed)), f"{arch}: params unchanged"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_shapes(arch, arch_setup):
    cfg, params = arch_setup(arch)
    if not cfg.supports_decode:
        pytest.skip("encoder-only arch has no decode step")
    batch = make_batch(cfg)
    logits, _ = T.prefill(cfg, params, batch)
    v = cfg.num_classes or cfg.vocab_size
    assert logits.shape == (B, v)
    assert bool(jnp.all(jnp.isfinite(logits)))

    cache = T.make_cache(cfg, B, L + 8)
    tok = jnp.ones((B,), jnp.int32)
    lg, new_cache = T.decode_step(cfg, params, tok, cache, jnp.int32(0))
    assert lg.shape == (B, v)
    assert bool(jnp.all(jnp.isfinite(lg)))
    # cache structure is preserved (jit-compatible fixed shapes)
    assert (jax.tree.structure(cache) == jax.tree.structure(new_cache))
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(new_cache)):
        assert a.shape == b.shape


@pytest.mark.parametrize("arch", ARCHS)
def test_shape_applicability_matrix(arch):
    """The skip table in DESIGN.md is encoded in shape_applicable."""
    cfg = get_config(arch)
    for shape in INPUT_SHAPES.values():
        ok, why = shape_applicable(cfg, shape)
        if shape.kind == "decode" and cfg.is_encoder:
            assert not ok
        if shape.name == "long_500k" and not cfg.is_encoder:
            assert ok == cfg.subquadratic, (arch, why)
        if shape.kind in ("train", "prefill"):
            assert ok


def test_assignment_pool_complete():
    assert len(ARCHS) == 10
    families = {get_config(a).family for a in ARCHS}
    assert families == {"dense", "moe", "ssm", "hybrid", "vlm", "audio"}
